"""Fault-tolerant execution tests: supervision, checkpointed restart with
replay, poison pills, prep-error auditing, and the deterministic
fault-injection sweep.

The core acceptance property throughout: a workflow crashed at ANY step
boundary (or mid-prefetch) under ``on_failure: restart`` produces results
byte-identical to the crash-free run -- restarts are invisible to the data.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import (Channel, ChannelError, FailurePolicy, FaultPlan,
                        FaultSpec, InjectedFault, PrefetchPool,
                        RecoveryContext, TelemetryTimeline, Wilkins, h5,
                        reshard_blocks, world)
from repro.core.datamodel import File
from repro.train.checkpoint import AsyncCheckpointer

STEPS = 4
N = 32


def _a(t):
    return np.arange(N, dtype=np.float64) + 100.0 * t


def _b(t):
    return 2.0 * np.arange(N, dtype=np.float64) + 1000.0 * t


#: expected crash-free results (pure functions of step -> closed form)
EXPECTED_C1 = sum(_a(t) for t in range(STEPS))
EXPECTED_C2 = sum(_a(t) + 3.0 * _b(t) for t in range(STEPS))


# 2 producers x 2 consumers, all under managed restart: p1 -> a.h5 fans out
# to BOTH consumers; p2 -> b.h5 feeds only c2 (so c2 exercises fan-in).
RECOVERY_YAML = """
tasks:
  - func: p1
    on_failure:
      restart: {max_retries: 3}
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: p2
    on_failure:
      restart: {max_retries: 3}
    outports:
      - filename: b.h5
        dsets:
          - {name: /h, memory: 1}
  - func: c1
    on_failure:
      restart: {max_retries: 3}
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: c2
    on_failure:
      restart: {max_retries: 3}
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
      - filename: b.h5
        dsets:
          - {name: /h, memory: 1}
"""


def _make_producer(filename, dset, gen):
    """Checkpoint-every-step producer: a restart resumes at the next step."""

    def produce(comm):
        start = 0
        r = comm.restore({"step": np.zeros((), np.int64)})
        if r is not None:
            start = int(r[1]["step"])
        for t in range(start, STEPS):
            with h5.File(filename, "w") as f:
                f.create_dataset(dset, data=gen(t))
            comm.checkpoint({"step": np.array(t + 1, np.int64)})

    return produce


def _make_consumer(results, key, primary, extras=()):
    """Stateful accumulator consumer with per-step checkpoints.

    ``primary`` is (filename, dset, weight) and drives loop termination;
    ``extras`` are further (filename, dset, weight) inports read in lockstep.
    Records the final accumulator, the step count, and the producer epochs
    observed (the ``wilkins_epoch`` attr stamped at serve time).
    """

    def consume(comm):
        like = {"acc": np.zeros(N, np.float64), "n": np.zeros((), np.int64)}
        state = like
        r = comm.restore(like)
        if r is not None:
            state = r[1]
        epochs = []
        while True:
            f0 = h5.File(primary[0], "r")
            if f0 is None:
                break
            epochs.append(int(f0.attrs.get("wilkins_epoch", -1)))
            acc = state["acc"] + primary[2] * f0[primary[1]][...]
            for fname, dset, w in extras:
                fx = h5.File(fname, "r")
                if fx is not None:
                    acc = acc + w * fx[dset][...]
            state = {"acc": acc, "n": state["n"] + np.int64(1)}
            comm.checkpoint(state)
        results[key] = (np.asarray(state["acc"]).copy(), int(state["n"]),
                        epochs)

    return consume


def _recovery_workflow(tmp_path, tag):
    results = {}
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "p2": _make_producer("b.h5", "/h", _b),
        "c1": _make_consumer(results, "c1", ("a.h5", "/g", 1.0)),
        "c2": _make_consumer(results, "c2", ("a.h5", "/g", 1.0),
                             extras=(("b.h5", "/h", 3.0),)),
    }
    w = Wilkins(RECOVERY_YAML, funcs, spill_dir=str(tmp_path / tag))
    return w, results


def _assert_byte_identical(results):
    acc1, n1, _ = results["c1"]
    acc2, n2, _ = results["c2"]
    assert n1 == STEPS and n2 == STEPS
    np.testing.assert_array_equal(acc1, EXPECTED_C1)
    np.testing.assert_array_equal(acc2, EXPECTED_C2)


# ---------------------------------------------------------------------------
# tentpole: crash -> restart -> byte-identical output
# ---------------------------------------------------------------------------
def test_crash_free_run_matches_reference(tmp_path):
    w, results = _recovery_workflow(tmp_path, "ref")
    rep = w.run(timeout=60)
    _assert_byte_identical(results)
    assert rep.restarts == []
    assert rep.dropped_tasks == []
    assert rep.scheduler["recovery"]["restarts"] == []
    # managed-restart policies are wired, so every serve carries epoch 0
    assert set(results["c1"][2]) == {0}


def test_consumer_crash_recovers_byte_identical(tmp_path):
    """The acceptance criterion: an injected consumer crash in the
    delivered-but-unseen window recovers under ``on_failure: restart`` with
    byte-identical output, and the restart is visible everywhere it should
    be (report, telemetry timeline, summary, scheduler snapshot)."""
    w, results = _recovery_workflow(tmp_path, "ccrash")
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c1", point="recv", step=1))
    _assert_byte_identical(results)

    assert len(rep.restarts) == 1
    ev = rep.restarts[0]
    assert ev["task"] == "c1" and ev["attempt"] == 0 and ev["epoch"] == 1
    assert "InjectedFault" in ev["reason"]
    # the payload was delivered before the crash, so the restarted
    # incarnation must get it again from the replay buffer
    assert sum(c.stats.replayed for c in rep.channels) >= 1
    # visibility: telemetry timeline, summary(), scheduler snapshot
    tl_events = rep.timeline.events("restart")
    assert len(tl_events) == 1 and tl_events[0]["task"] == "c1"
    assert "RESTART c1[0]" in rep.summary()
    assert "recovery:" in rep.summary()
    rec = rep.scheduler["recovery"]
    assert len(rec["restarts"]) == 1
    assert rec["states"]["c1[0]"] == "DONE"
    assert rec["faults_fired"] == 1
    assert rep.scheduler["restarts"] == 1


def test_producer_crash_recovers_byte_identical(tmp_path):
    w, results = _recovery_workflow(tmp_path, "pcrash")
    rep = w.run(timeout=60,
                faults=FaultSpec(task="p1", point="close", step=1))
    _assert_byte_identical(results)
    assert [r["task"] for r in rep.restarts] == ["p1"]
    # steps produced after the restart carry the new incarnation's epoch
    assert 1 in results["c1"][2]


def test_multi_fault_run_recovers(tmp_path):
    """A producer and a consumer both crash in one run; still byte-identical."""
    w, results = _recovery_workflow(tmp_path, "multi")
    rep = w.run(timeout=60, faults=[
        FaultSpec(task="p2", point="close", step=2),
        FaultSpec(task="c2", point="recv", step=3),
    ])
    _assert_byte_identical(results)
    assert sorted(r["task"] for r in rep.restarts) == ["c2", "p2"]


def test_stall_fault_does_not_restart(tmp_path):
    """stall/slow_io faults delay but never crash: no restarts, same bytes."""
    w, results = _recovery_workflow(tmp_path, "stall")
    rep = w.run(timeout=60, faults=[
        FaultSpec(task="p1", kind="stall", point="close", step=1,
                  seconds=0.05),
        FaultSpec(task="c1", kind="slow_io", point="recv", step=0,
                  seconds=0.05),
    ])
    _assert_byte_identical(results)
    assert rep.restarts == []
    assert rep.scheduler["recovery"]["faults_fired"] == 2


# ---------------------------------------------------------------------------
# the deterministic fault-injection sweep (satellite: every task, every
# step boundary, plus the delivered-but-unseen window)
# ---------------------------------------------------------------------------
def _sweep_cases():
    cases = []
    for t in ("p1", "p2", "c1", "c2"):
        cases.append((t, "start", 0))
    for t in ("p1", "p2"):
        for s in range(STEPS):
            cases.append((t, "close", s))
    for pt in ("open", "recv"):
        for s in range(STEPS):
            cases.append(("c1", pt, s))
        # c2 opens two files per loop iteration, so its open/recv step
        # counter runs 0..2*STEPS-1 (even = a.h5, odd = b.h5)
        for s in range(2 * STEPS):
            cases.append(("c2", pt, s))
    return cases


SWEEP = _sweep_cases()
#: fast representative subset: first/last step boundary per task, both the
#: pre-delivery (open) and post-delivery (recv) windows, and a mid-stream b.h5
FAST_SWEEP = [
    ("p1", "close", 0), ("p2", "close", STEPS - 1),
    ("c1", "open", 2), ("c1", "recv", STEPS - 1),
    ("c2", "recv", 3), ("c2", "open", 5),
]


def _run_sweep_case(tmp_path, task, point, step):
    w, results = _recovery_workflow(tmp_path, f"{task}_{point}_{step}")
    rep = w.run(timeout=60,
                faults=FaultSpec(task=task, point=point, step=step))
    assert rep.scheduler["recovery"]["faults_fired"] == 1, \
        f"fault {task}/{point}/{step} never fired"
    assert [r["task"] for r in rep.restarts] == [task]
    _assert_byte_identical(results)


@pytest.mark.parametrize("task,point,step", FAST_SWEEP)
def test_fault_sweep_representative(tmp_path, task, point, step):
    _run_sweep_case(tmp_path, task, point, step)


@pytest.mark.slow
@pytest.mark.parametrize("task,point,step", SWEEP)
def test_fault_sweep_exhaustive(tmp_path, task, point, step):
    """Crash every task at every step boundary; output is always identical."""
    _run_sweep_case(tmp_path, task, point, step)


def test_mid_prefetch_crash_recovers_via_prep_retry(tmp_path):
    """A crash inside the async payload prep surfaces in the future; with a
    fault plan active the delivery path re-runs the (idempotent) prep
    synchronously -- no restart, no lost step, nothing in prefetch_errors."""
    yaml_text = """
tasks:
  - func: p1
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: c1
    inports:
      - filename: a.h5
        prefetch: 2
        queue_depth: 2
        dsets:
          - {name: /g, memory: 1}
"""
    results = {}
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "c1": _make_consumer(results, "c1", ("a.h5", "/g", 1.0)),
    }
    w = Wilkins(yaml_text, funcs, spill_dir=str(tmp_path / "prep"))
    rep = w.run(timeout=60,
                faults=FaultSpec(task="p1", point="prefetch", step=1))
    acc, n, _ = results["c1"]
    assert n == STEPS
    np.testing.assert_array_equal(acc, EXPECTED_C1)
    assert sum(c.stats.prep_retries for c in rep.channels) == 1
    assert rep.restarts == []
    assert rep.prefetch_errors == []  # observed + retried, not dropped
    assert "prep_retries=1" in rep.summary()


# ---------------------------------------------------------------------------
# poison pill (satellite: consumer blocked on a dead producer)
# ---------------------------------------------------------------------------
def _channel(tmp_path, **kw):
    kw.setdefault("mode", "memory")
    return Channel("p[0]->c[0]:x.h5", ("p", 0), ("c", 0), "x.h5", ["*"],
                   spill_dir=str(tmp_path), **kw)


def _file(step=0):
    f = File("x.h5")
    f.create_dataset("/g", data=_a(step))
    return f


def test_poison_wakes_blocked_get_immediately(tmp_path):
    """A consumer blocked in ``get()`` learns of the producer's death NOW,
    with the dead task named and the real error chained -- not an opaque
    ``ChannelTimeout`` thirty seconds later."""
    ch = _channel(tmp_path)
    out = {}

    def blocked_consumer():
        t0 = time.monotonic()
        try:
            ch.get(timeout=30.0)
        except BaseException as e:
            out["err"] = e
        out["elapsed"] = time.monotonic() - t0

    th = threading.Thread(target=blocked_consumer)
    th.start()
    time.sleep(0.2)  # let it block
    cause = RuntimeError("simulation diverged")
    ch.poison("sim", 3, cause)
    th.join(timeout=10)
    assert not th.is_alive()
    err = out["err"]
    assert isinstance(err, ChannelError)
    assert err.task == "sim" and err.instance == 3
    assert "sim" in str(err) and "simulation diverged" in str(err)
    assert err.__cause__ is cause
    assert out["elapsed"] < 10.0  # woke on poison, not on the timeout


def test_poison_delivers_queued_data_first(tmp_path):
    ch = _channel(tmp_path, queue_depth=2)
    assert ch.offer(_file(0))
    ch.poison("sim", 0, RuntimeError("late failure"))
    f = ch.get(timeout=5)  # pre-failure data still delivers
    np.testing.assert_array_equal(f["/g"][...], _a(0))
    with pytest.raises(ChannelError):
        ch.get(timeout=5)
    with pytest.raises(ChannelError):
        ch.try_get()
    assert ch.is_done()  # terminal: the driver stops relaunching consumers


def test_workflow_poison_names_dead_producer(tmp_path):
    """End-to-end satellite: producer dies mid-run under the default
    ``on_failure: fail``; the blocked consumer (in the ChannelMux wait path)
    raises a chained ChannelError naming the producer, and the run fails
    fast instead of riding out its timeout."""
    yaml_text = """
tasks:
  - func: bad
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: victim
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
"""
    seen = []

    def bad():
        with h5.File("a.h5", "w") as f:
            f.create_dataset("/g", data=_a(0))
        raise ValueError("disk on fire")

    def victim():
        while True:
            f = h5.File("a.h5", "r")
            if f is None:
                break
            seen.append(int(f["/g"][0]))

    w = Wilkins(yaml_text, {"bad": bad, "victim": victim},
                spill_dir=str(tmp_path / "poison"))
    t0 = time.monotonic()
    with pytest.raises(Exception) as ei:
        w.run(timeout=60)
    assert time.monotonic() - t0 < 30.0  # failed fast, not at the deadline

    chain, e = [], ei.value
    while e is not None:
        chain.append(e)
        e = e.__context__
    assert any(isinstance(e, ValueError) and "disk on fire" in str(e)
               for e in chain)
    poisons = [e for e in chain if isinstance(e, ChannelError)]
    assert poisons and poisons[0].task == "bad"
    assert poisons[0].__cause__ is not None
    rep = ei.value.report
    assert {f.task for f in rep.failures} == {"bad", "victim"}
    assert seen == [0]  # pre-failure data was still delivered


# ---------------------------------------------------------------------------
# prefetch-pool error audit (satellite: shutdown race never eats errors)
# ---------------------------------------------------------------------------
def test_prep_error_after_shutdown_is_drained():
    pool = PrefetchPool(max_workers=1)
    started, release = threading.Event(), threading.Event()

    def doomed_prep():
        started.set()
        release.wait(10)
        raise RuntimeError("prep exploded after teardown")

    fut = pool.submit(doomed_prep, edge="p[0]->c[0]:a.h5")
    assert started.wait(5)
    pool.shutdown(cancel_pending=True)  # prep is RUNNING: cannot be cancelled
    release.set()  # now it errors, with nobody left to call fut.result()
    errs = pool.drain_errors(timeout=10)
    assert len(errs) == 1
    edge, exc = errs[0]
    assert edge == "p[0]->c[0]:a.h5"
    assert isinstance(exc, RuntimeError) and "prep exploded" in str(exc)
    assert fut.exception() is not None
    assert pool.drain_errors(timeout=1) == []  # reported exactly once


def test_drain_skips_cancelled_and_observed_preps():
    pool = PrefetchPool(max_workers=1)
    gate = threading.Event()

    def blocker():
        gate.wait(10)
        raise RuntimeError("observed by the consumer")

    def never_runs():  # pragma: no cover - cancelled before starting
        raise AssertionError("queued prep must be cancelled at shutdown")

    f1 = pool.submit(blocker, edge="e1")
    time.sleep(0.1)  # worker claims f1; f2 stays queued
    f2 = pool.submit(never_runs, edge="e2")
    pool.shutdown(cancel_pending=True)
    gate.set()
    # consumer DID see f1's error (the delivery path marks it observed)
    while not f1.done():
        time.sleep(0.01)
    f1._wilkins_observed = True
    assert f2.cancelled()
    assert pool.drain_errors(timeout=10) == []


# ---------------------------------------------------------------------------
# channel-level recovery protocol units (dedup / replay / ack watermarks)
# ---------------------------------------------------------------------------
def test_offer_dedups_restarted_producer_serves(tmp_path):
    """A restarted producer rewound past the consumer's delivery watermark
    regenerates serves the consumer already holds: recognized and skipped
    (exactly-once), while genuinely new steps still flow."""
    ch = _channel(tmp_path)
    assert ch.offer(_file(0))
    f = ch.get(timeout=5)
    np.testing.assert_array_equal(f["/g"][...], _a(0))
    # producer dies with nothing acked: rewind to serve_seq 0
    ch.quarantine_producer(epoch=1)
    assert ch.epoch == 1
    # restarted producer regenerates step 0 -> duplicate, swallowed
    assert ch.offer(_file(0)) is True
    assert ch.stats.deduped == 1
    assert not ch.peek_pending()
    # ...and produces step 1 -> genuinely new, delivered
    assert ch.offer(_file(1))
    np.testing.assert_array_equal(ch.get(timeout=5)["/g"][...], _a(1))


def test_quarantine_consumer_replays_unacked_deliveries(tmp_path):
    ch = _channel(tmp_path)
    ch.set_replay(True)
    assert ch.offer(_file(0))
    np.testing.assert_array_equal(ch.get(timeout=5)["/g"][...], _a(0))
    # consumer dies before checkpointing: the delivery must replay
    ch.quarantine_consumer(epoch=1)
    assert ch.stats.replayed == 1
    np.testing.assert_array_equal(ch.get(timeout=5)["/g"][...], _a(0))
    # checkpoint acks it; a second quarantine replays nothing
    ch.ack_consumer()
    ch.quarantine_consumer(epoch=2)
    assert ch.stats.replayed == 1
    assert not ch.peek_pending()


def test_quarantine_producer_keeps_acked_queued_payloads(tmp_path):
    ch = _channel(tmp_path, queue_depth=4)
    assert ch.offer(_file(0))
    ch.ack_producer()  # step 0 is durable (producer checkpointed)
    assert ch.offer(_file(1))  # step 1 is not
    ch.quarantine_producer(epoch=1)
    # acked payload survives the quarantine, un-acked one is dropped
    np.testing.assert_array_equal(ch.get(timeout=5)["/g"][...], _a(0))
    assert not ch.peek_pending()
    assert ch.stats.dropped == 1
    # the restarted producer re-serves step 1 under the new epoch
    assert ch.offer(_file(1))
    np.testing.assert_array_equal(ch.get(timeout=5)["/g"][...], _a(1))


def test_abandon_consumer_turns_offers_into_drops(tmp_path):
    ch = _channel(tmp_path)
    assert ch.offer(_file(0))
    ch.abandon_consumer()
    assert ch.offer(_file(1)) is False  # no block, no queue growth
    assert ch.stats.dropped >= 2  # the queued payload + the new serve
    assert not ch.peek_pending()


# ---------------------------------------------------------------------------
# policies: drop, exhaustion, legacy compatibility
# ---------------------------------------------------------------------------
DROP_YAML = """
tasks:
  - func: p1
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: cmain
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: copt
    on_failure: drop
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
"""


def test_drop_policy_degrades_optional_task_to_noop(tmp_path):
    """An optional analysis task under ``on_failure: drop`` dies; the rest
    of the workflow runs to completion, the producer's serves toward the
    dead task become counted drops, and the outcome is visible."""
    results = {}
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "cmain": _make_consumer(results, "cmain", ("a.h5", "/g", 1.0)),
        "copt": _make_consumer(results, "copt", ("a.h5", "/g", 1.0)),
    }
    w = Wilkins(DROP_YAML, funcs, spill_dir=str(tmp_path / "drop"))
    rep = w.run(timeout=60,
                faults=FaultSpec(task="copt", point="open", step=1))
    acc, n, _ = results["cmain"]
    assert n == STEPS
    np.testing.assert_array_equal(acc, EXPECTED_C1)
    assert "copt" not in results  # never finished -- degraded to a no-op
    assert rep.dropped_tasks == [("copt", 0)]
    assert rep.restarts == []
    assert len(rep.failures) == 1 and rep.failures[0].task == "copt"
    assert "DROPPED copt[0]" in rep.summary()
    assert rep.timeline.events("drop")[0]["task"] == "copt"
    assert rep.scheduler["recovery"]["states"]["copt[0]"] == "DROPPED"


def test_max_retries_exhaustion_chains_all_errors(tmp_path):
    """A task that crashes on every incarnation exhausts its budget; the run
    fails with EVERY attempt's error reachable on the __context__ chain and
    the partial report attached (PR 3 semantics preserved)."""
    yaml_text = """
tasks:
  - func: p1
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: c1
    on_failure:
      restart: {max_retries: 1}
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
"""
    results = {}
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "c1": _make_consumer(results, "c1", ("a.h5", "/g", 1.0)),
    }
    w = Wilkins(yaml_text, funcs, spill_dir=str(tmp_path / "exh"))
    with pytest.raises(InjectedFault) as ei:
        # attempt=None, times=None: crash EVERY incarnation at open
        w.run(timeout=60, faults=FaultSpec(task="c1", point="open", step=0,
                                           attempt=None, times=None))
    rep = ei.value.report
    # both incarnations failed and both are on the report
    assert [(f.task, f.attempt) for f in rep.failures] == \
        [("c1", 0), ("c1", 1)]
    # the one restart that was granted is recorded before exhaustion
    assert len(rep.restarts) == 1
    assert rep.scheduler["recovery"]["states"]["c1[0]"] == "FAILED"
    # the producer was not left hanging toward the dead consumer
    assert ("p1", 0) in rep.task_times


def test_legacy_max_restarts_stays_unmanaged(tmp_path):
    """``Wilkins(max_restarts=N)`` with no YAML ``on_failure`` keeps the
    pre-recovery in-place relaunch: no RestartEvents, no epochs, no
    channel surgery -- and the flaky task still completes."""
    yaml_text = """
tasks:
  - func: flaky
    outports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
  - func: c1
    inports:
      - filename: a.h5
        dsets:
          - {name: /g, memory: 1}
"""
    attempts = {"n": 0}
    got = []

    def flaky():
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise RuntimeError("transient")
        for t in range(2):
            with h5.File("a.h5", "w") as f:
                f.create_dataset("/g", data=_a(t))

    def c1():
        while True:
            f = h5.File("a.h5", "r")
            if f is None:
                break
            got.append(int(f["/g"][0]))

    w = Wilkins(yaml_text, {"flaky": flaky, "c1": c1}, max_restarts=2,
                spill_dir=str(tmp_path / "legacy"))
    rep = w.run(timeout=60)
    assert attempts["n"] == 2
    assert got == [0, 100]
    assert rep.restarts == []  # unmanaged: no recovery protocol engaged
    assert len(rep.failures) == 1
    # no supervisor attached -> served files carry no epoch stamp
    assert rep.scheduler["recovery"]["restarts"] == []


# ---------------------------------------------------------------------------
# policy / fault-spec parsing
# ---------------------------------------------------------------------------
def test_failure_policy_parses_all_spellings():
    assert FailurePolicy.from_yaml(None).kind == "fail"
    assert FailurePolicy.from_yaml("fail").kind == "fail"
    assert FailurePolicy.from_yaml("drop").kind == "drop"
    p = FailurePolicy.from_yaml("restart")
    assert p.kind == "restart" and p.max_retries == 1 and p.managed
    p = FailurePolicy.from_yaml(
        {"restart": {"max_retries": 5, "backoff_s": 0.25, "jitter": 0.1}},
        task="sim")
    assert (p.kind, p.max_retries, p.backoff_s, p.jitter) == \
        ("restart", 5, 0.25, 0.1)


@pytest.mark.parametrize("doc", [
    "explode",
    {"retry": {"max_retries": 2}},
    {"restart": "yes"},
    {"restart": {"max_retries": 0}},
    {"restart": {"backoff_s": -1}},
    {"restart": {"jitter": -0.5}},
    {"restart": {"bogus": 1}},
    17,
])
def test_failure_policy_rejects_bad_yaml_naming_the_task(doc):
    with pytest.raises(ValueError, match="task 'sim'"):
        FailurePolicy.from_yaml(doc, task="sim")


def test_backoff_is_deterministic_and_exponential():
    p = FailurePolicy(kind="restart", max_retries=3, backoff_s=0.1,
                      jitter=0.05)
    assert p.backoff("t", 0, 1) == p.backoff("t", 0, 1)  # no RNG
    assert p.backoff("t", 0, 2) > p.backoff("t", 0, 1) > p.backoff("t", 0, 0)
    assert FailurePolicy().backoff("t", 0, 5) == 0.0


def test_fault_spec_validation_and_coercion():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(task="t", kind="explode")
    with pytest.raises(ValueError, match="point"):
        FaultSpec(task="t", point="nowhere")
    with pytest.raises(ValueError, match="times"):
        FaultSpec(task="t", times=0)
    with pytest.raises(ValueError, match="seconds"):
        FaultSpec(task="t", seconds=-1.0)
    assert FaultPlan.coerce(None) is None
    plan = FaultPlan.coerce(FaultSpec(task="t"))
    assert isinstance(plan, FaultPlan) and len(plan.specs) == 1
    assert FaultPlan.coerce(plan) is plan
    plan2 = FaultPlan.coerce([{"task": "t", "point": "open", "step": 2}])
    assert plan2.specs[0].step == 2
    # invalid YAML on_failure reaches Wilkins construction as a clear error
    with pytest.raises(ValueError, match="task 'x'"):
        Wilkins("tasks:\n  - func: x\n    on_failure: explode\n",
                {"x": lambda: None})


def test_fault_plan_times_budget():
    plan = FaultPlan([FaultSpec(task="t", point="open", step=None,
                                attempt=None, times=2)])
    for _ in range(2):
        with pytest.raises(InjectedFault):
            plan.fire("t", 0, "open", 0, 0)
    plan.fire("t", 0, "open", 0, 0)  # budget exhausted: no longer fires
    assert plan.fired() == 2
    assert len(plan.log) == 2


# ---------------------------------------------------------------------------
# checkpoint surface: TaskComm, RecoveryContext, reshard replay
# ---------------------------------------------------------------------------
def test_checkpoint_restore_are_noops_standalone():
    comm = world()
    assert comm.recovery is None
    assert comm.checkpoint({"x": np.arange(3)}) is None
    assert comm.restore({"x": np.zeros(3)}) is None
    assert comm.attempt == 0 and comm.epoch == 0


def test_recovery_context_checkpoint_acks_and_restores(tmp_path):
    class FakeCh:
        def __init__(self):
            self.producer_acks = 0
            self.consumer_acks = 0

        def ack_producer(self):
            self.producer_acks += 1

        def ack_consumer(self):
            self.consumer_acks += 1

    cin, cout = FakeCh(), FakeCh()
    rc = RecoveryContext("sim", 0, str(tmp_path / "ck"),
                         incoming=[cin], outgoing=[cout])
    assert rc.restore({"x": np.zeros(4)}) is None  # fresh start
    assert rc.checkpoint({"x": np.arange(4.0)}) == 0
    assert rc.checkpoint({"x": np.arange(4.0) * 2}) == 1
    assert cout.producer_acks == 2 and cin.consumer_acks == 2

    # a NEW incarnation (fresh context over the same directory) restores
    rc2 = RecoveryContext("sim", 0, str(tmp_path / "ck"))
    step, state = rc2.restore({"x": np.zeros(4)})
    assert step == 1
    np.testing.assert_array_equal(state["x"], np.arange(4.0) * 2)
    assert rc2.checkpoint({"x": np.zeros(4)}) == 2  # resumes the step count


def test_reshard_blocks_m_to_n():
    """State checkpointed by M ranks restores onto N ranks through the plan
    cache -- the concatenation is invariant, the splits are the even N-way
    decomposition."""
    g = np.arange(36, dtype=np.float64).reshape(12, 3)
    blocks3 = np.array_split(g, 3, axis=0)
    out2 = reshard_blocks(blocks3, 2)
    assert len(out2) == 2
    np.testing.assert_array_equal(np.concatenate(out2, axis=0), g)
    out5 = reshard_blocks(out2, 5)
    np.testing.assert_array_equal(np.concatenate(out5, axis=0), g)
    # non-zero axis
    outc = reshard_blocks(np.array_split(g, 3, axis=1), 2, axis=1)
    np.testing.assert_array_equal(np.concatenate(outc, axis=1), g)
    with pytest.raises(ValueError, match="at least one"):
        reshard_blocks([], 2)
    with pytest.raises(ValueError, match="new_nranks"):
        reshard_blocks(blocks3, 0)
    with pytest.raises(ValueError, match="axis"):
        reshard_blocks(blocks3, 2, axis=7)


def test_async_checkpointer_surfaces_background_write_errors(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path / "ok"))
    ck.dir = str(tmp_path / "missing" / "deeper")  # writes now fail
    ck.save(0, {"x": np.arange(3)})
    with pytest.raises(FileNotFoundError):
        ck.wait()
    # the parked error is cleared once raised; recovery is possible
    ck.dir = str(tmp_path / "ok")
    ck.save(1, {"x": np.arange(3)}, block=True)
    assert ck.latest_step() == 1
    # block=True re-raises synchronously on the caller
    ck.dir = str(tmp_path / "missing" / "deeper")
    with pytest.raises(FileNotFoundError):
        ck.save(2, {"x": np.arange(3)}, block=True)


def test_timeline_events_survive_json_roundtrip():
    tl = TelemetryTimeline(capacity=0)  # sampling off; events still record
    tl.record_event("restart", task="sim", instance=0, attempt=0, epoch=1,
                    reason="InjectedFault: boom")
    tl.record_event("drop", task="viz", instance=1)
    assert len(tl.events()) == 2
    assert tl.events("restart")[0]["epoch"] == 1
    tl2 = TelemetryTimeline.from_json(tl.to_json())
    assert tl2.events("restart") == tl.events("restart")
    assert [e["kind"] for e in tl2.events()] == ["restart", "drop"]
