"""Runtime-scheduling subsystem: weighted-fair prefetch arbitration, depth
autotuning, the telemetry timeline, and the prefetch lifecycle satellites
(slot-leak on shutdown, `latest` x prefetch stale-prep cancellation)."""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import Wilkins, WorkflowGraph, h5
from repro.core.channel import Channel, PrefetchPool
from repro.core.datamodel import (File, reset_transport_stats,
                                  transport_stats)
from repro.core.redistribute import RedistSpec
from repro.core.scheduler import (DepthAutotuner, FairPolicy, FifoPolicy,
                                  ResizableSemaphore, SchedulerConfig,
                                  SchedulerRuntime, TelemetryTimeline)


def _mk_channel(name="e", prefetch=1, autotune=None, weight=1, io_freq=1,
                queue_depth=4, slot=0):
    return Channel(name, ("p", 0), ("c", slot), "o.h5", ["/g"],
                   io_freq=io_freq, queue_depth=queue_depth,
                   redistribute=RedistSpec(axis=0, nslots=2, slot=slot,
                                           nranks=1),
                   prefetch=prefetch, weight=weight, autotune=autotune)


def _file(n=16):
    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(float(n)))
    return f


# ---------------------------------------------------------------------------
# queue policies
# ---------------------------------------------------------------------------
def test_fifo_policy_preserves_submission_order():
    pol = FifoPolicy()
    for i in range(10):
        pol.push(i, edge=f"e{i % 3}", weight=i + 1)
    assert [pol.pop() for _ in range(10)] == list(range(10))
    assert pol.pop() is None and pol.pending() == 0


def test_fair_policy_weighted_shares():
    """Weights 3:1 -> the heavy edge gets ~3x the pops while both edges
    stay backlogged; the acceptance bar is >= 2:1 over the first window."""
    pol = FairPolicy()
    for i in range(30):
        pol.push(("hot", i), edge="hot", weight=3)
        pol.push(("cold", i), edge="cold", weight=1)
    first = [pol.pop()[0] for _ in range(20)]
    hot = first.count("hot")
    cold = first.count("cold")
    assert hot >= 2 * cold, (hot, cold)
    assert cold >= 1  # no starvation: the weight-1 edge still progresses
    # full drain serves everything exactly once
    rest = []
    while pol.pending():
        rest.append(pol.pop())
    assert len(first) + len(rest) == 60


def test_fair_policy_idle_edge_does_not_hoard_credit():
    pol = FairPolicy()
    pol.push("a1", edge="a", weight=5)
    assert pol.pop() == "a1"          # edge a drains; its deficit resets
    for i in range(4):
        pol.push(("b", i), edge="b", weight=1)
    pol.push("a2", edge="a", weight=5)
    got = [pol.pop() for _ in range(5)]
    assert set(got) == {("b", 0), ("b", 1), ("b", 2), ("b", 3), "a2"}


def test_fair_policy_drain_returns_everything():
    pol = FairPolicy()
    for i in range(7):
        pol.push(i, edge=f"e{i % 2}")
    pol.pop()
    drained = pol.drain()
    assert len(drained) == 6 and pol.pending() == 0
    assert pol.pop() is None


def test_pool_fifo_default_serves_in_order():
    """The default pool policy is FIFO: one worker, submission order ==
    completion order (bit-for-bit the pre-scheduler behaviour)."""
    pool = PrefetchPool(max_workers=1)
    order = []
    gate = threading.Event()
    first = pool.submit(lambda: gate.wait(5))
    futs = [pool.submit(lambda i=i: order.append(i)) for i in range(5)]
    gate.set()
    for f in futs:
        f.result(timeout=5)
    assert order == list(range(5))
    pool.shutdown()


def test_pool_fair_policy_respects_weights():
    pool = PrefetchPool(max_workers=1, policy=FairPolicy())
    order = []
    gate = threading.Event()
    pool.submit(lambda: gate.wait(5))  # park the worker so queues build
    futs = []
    for i in range(6):
        futs.append(pool.submit(lambda: order.append("hot"),
                                edge="hot", weight=3))
        futs.append(pool.submit(lambda: order.append("cold"),
                                edge="cold", weight=1))
    gate.set()
    for f in futs:
        f.result(timeout=5)
    window = order[:8]
    assert window.count("hot") >= 2 * window.count("cold"), window
    pool.shutdown()


# ---------------------------------------------------------------------------
# resizable semaphore
# ---------------------------------------------------------------------------
def test_resizable_semaphore_bounds_and_overrelease():
    sem = ResizableSemaphore(2)
    assert sem.acquire(timeout=1) and sem.acquire(timeout=1)
    assert not sem.acquire(timeout=0.05)   # at the limit
    sem.release()
    sem.release()
    with pytest.raises(ValueError, match="released too many times"):
        sem.release()


def test_resizable_semaphore_grow_wakes_waiter_and_shrink_drains():
    sem = ResizableSemaphore(1)
    assert sem.acquire(timeout=1)
    got = threading.Event()

    def waiter():
        if sem.acquire(timeout=5):
            got.set()

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not got.is_set()
    sem.resize(2)                  # grow: the blocked waiter proceeds
    assert got.wait(5)
    sem.resize(1)                  # shrink below in-use: just drains
    assert sem.in_use == 2 and sem.limit == 1
    sem.release()
    sem.release()
    assert sem.in_use == 0


# ---------------------------------------------------------------------------
# satellite: shutdown slot-leak regression
# ---------------------------------------------------------------------------
def test_shutdown_mid_flight_releases_every_depth_slot():
    """Queued preps cancelled by PrefetchPool.shutdown() must release their
    edge's semaphore slot via the done-callback -- fully released, and no
    ValueError from an over-release either."""
    ch = _mk_channel(prefetch=3, queue_depth=8)
    pool = PrefetchPool(max_workers=1)
    ch.set_prefetch_pool(pool)
    gate = threading.Event()
    started = threading.Event()
    orig = ch._prepare

    def slow_prepare(*a, **kw):
        started.set()
        gate.wait(5)
        return orig(*a, **kw)

    ch._prepare = slow_prepare
    f = _file()
    assert ch.offer(f)
    assert started.wait(5)      # the first prep is RUNNING on the worker
    for _ in range(2):          # two more queue behind it; all 3 slots held
        assert ch.offer(f)
    assert ch._prefetch_sem.in_use == 3
    assert ch.stats.inflight_preps == 3
    pool.shutdown()             # cancels the 2 queued preps
    gate.set()                  # lets the running prep finish
    deadline = time.monotonic() + 5
    while ch._prefetch_sem.in_use and time.monotonic() < deadline:
        time.sleep(0.01)
    assert ch._prefetch_sem.in_use == 0          # no slot leaked
    assert ch.stats.inflight_preps == 0
    assert ch.stats.prefetch_cancelled == 2      # the queued pair
    # over-release is still an error (the callback ran exactly once each)
    with pytest.raises(ValueError):
        ch._prefetch_sem.release()


# ---------------------------------------------------------------------------
# satellite: `latest` x prefetch stale-prep cancellation
# ---------------------------------------------------------------------------
def test_latest_edge_cancels_stale_inflight_prep():
    reset_transport_stats()
    ch = _mk_channel(prefetch=2, io_freq=-1, queue_depth=4)
    pool = PrefetchPool(max_workers=1)
    ch.set_prefetch_pool(pool)
    gate = threading.Event()
    orig = ch._prepare

    def slow_prepare(*a, **kw):
        gate.wait(5)
        return orig(*a, **kw)

    ch._prepare = slow_prepare
    f = _file()
    ch.set_consumer_waiting(True)   # `latest` serves only waiting consumers
    try:
        assert ch.offer(f)          # step 0: prep starts (worker blocked)
        assert ch.offer(f)          # step 1: supersedes step 0's queued prep
        with ch._lock:
            assert len(ch._queue) == 1          # stale future replaced
        assert ch.stats.dropped == 1
    finally:
        ch.set_consumer_waiting(False)
    gate.set()
    ch.finish()
    got = ch.get(timeout=5)         # the fresh step delivers fine
    assert got is not None
    deadline = time.monotonic() + 5
    while ch._prefetch_sem.in_use and time.monotonic() < deadline:
        time.sleep(0.01)
    assert ch._prefetch_sem.in_use == 0
    # exactly one prep was dropped as stale, counted in transport stats
    assert transport_stats().snapshot()["prefetch_cancelled"] == 1
    assert ch.stats.prefetch_cancelled == 1
    pool.shutdown()


def test_latest_edge_keeps_finished_payloads():
    """A COMPLETED future is fresh data, not a stale prep: it must survive
    the supersede pass and deliver."""
    ch = _mk_channel(prefetch=2, io_freq=-1, queue_depth=4)
    pool = PrefetchPool(max_workers=2)
    ch.set_prefetch_pool(pool)
    f = _file()
    ch.set_consumer_waiting(True)
    try:
        assert ch.offer(f)
        with ch._lock:
            fut = ch._queue[0][1]
        fut.result(timeout=5)       # prep done before the next offer
        time.sleep(0.02)            # let the done-callback run
        assert ch.offer(f)
        with ch._lock:
            assert len(ch._queue) == 2      # nothing dropped
        assert ch.stats.dropped == 0
    finally:
        ch.set_consumer_waiting(False)
    pool.shutdown()


# ---------------------------------------------------------------------------
# depth autotuner
# ---------------------------------------------------------------------------
def test_autotuner_grows_blocked_edge_within_bounds():
    ch = _mk_channel(prefetch=1, autotune=(1, 3))
    tuner = DepthAutotuner()
    tuner.tick([ch])                       # baseline
    for i in range(5):                     # keep signalling "blocked"
        with ch._lock:
            ch.stats.prefetch_misses += 2
            ch.stats.prefetch_blocked_s += 0.1
            ch.stats.served += 2
        tuner.tick([ch])
    assert ch.prefetch == 3                # grew, then pinned at max
    assert ch._prefetch_sem.limit == 3
    grow = [d for d in tuner.decisions if "grow" in d.reason]
    assert len(grow) == 2 and grow[0].old == 1 and grow[-1].new == 3


def test_autotuner_shrinks_idle_edge_with_hysteresis():
    ch = _mk_channel(prefetch=3, autotune=(1, 4))
    tuner = DepthAutotuner()
    tuner.tick([ch])                       # baseline
    for _ in range(6):                     # all hits, nothing blocked
        with ch._lock:
            ch.stats.prefetch_hits += 2
            ch.stats.served += 2
        tuner.tick([ch])
    assert ch.prefetch < 3                 # narrowed...
    assert ch.prefetch >= 1                # ...but never below min
    # hysteresis: first shrink needed two idle ticks, not one
    shrinks = [d for d in tuner.decisions if "idle" in d.reason]
    assert shrinks and shrinks[0].old == 3


def test_autotuner_idle_hysteresis_requires_consecutive_ticks():
    """A hold tick between two idle ticks restarts the shrink count: idle,
    hold, idle must NOT shrink (the documented rule is 2 CONSECUTIVE)."""
    ch = _mk_channel(prefetch=3, autotune=(1, 4))
    tuner = DepthAutotuner()
    tuner.tick([ch])                       # baseline
    with ch._lock:                         # idle tick 1
        ch.stats.prefetch_hits += 1
        ch.stats.served += 1
    tuner.tick([ch])
    tuner.tick([ch])                       # hold tick (no activity at all)
    with ch._lock:                         # idle tick again -- count restarts
        ch.stats.prefetch_hits += 1
        ch.stats.served += 1
    tuner.tick([ch])
    assert ch.prefetch == 3 and not tuner.decisions


def test_autotuner_shrinks_on_cancelled_preps():
    ch = _mk_channel(prefetch=3, autotune=(1, 4))
    tuner = DepthAutotuner()
    tuner.tick([ch])
    with ch._lock:
        ch.stats.prefetch_cancelled += 1
        ch.stats.served += 1
    tuner.tick([ch])
    assert ch.prefetch == 2
    assert any("cancelled" in d.reason for d in tuner.decisions)


def test_autotuner_ignores_non_autotuned_channels():
    ch = _mk_channel(prefetch=2, autotune=None)
    tuner = DepthAutotuner()
    tuner.tick([ch])
    with ch._lock:
        ch.stats.prefetch_misses += 5
        ch.stats.prefetch_blocked_s += 1.0
    tuner.tick([ch])
    assert ch.prefetch == 2 and not tuner.decisions


def test_set_depth_requires_prefetch_machinery():
    ch = Channel("c", ("p", 0), ("c", 0), "o.h5", ["/g"])  # prefetch off
    with pytest.raises(ValueError, match="without prefetch"):
        ch.set_depth(2)
    ch2 = _mk_channel(prefetch=2)
    with pytest.raises(ValueError, match=">= 1"):
        ch2.set_depth(0)


# ---------------------------------------------------------------------------
# telemetry timeline
# ---------------------------------------------------------------------------
def test_timeline_samples_and_json_roundtrip(tmp_path):
    chans = [_mk_channel(name=f"e{i}", prefetch=1, slot=i % 2)
             for i in range(2)]
    tl = TelemetryTimeline(capacity=64)
    for _ in range(3):
        tl.sample(chans)
    assert tl.per_edge_counts() == {"e0": 3, "e1": 3}
    path = str(tmp_path / "timeline.json")
    tl.export(path)
    back = TelemetryTimeline.load(path)
    assert back.per_edge_counts() == tl.per_edge_counts()
    assert back.samples() == tl.samples()
    doc = json.loads(tl.to_json())
    assert doc["version"] == 1 and len(doc["samples"]) == 6


def test_timeline_ring_bounds_and_counts_drops():
    ch = _mk_channel(prefetch=1)
    tl = TelemetryTimeline(capacity=4)
    for _ in range(6):
        tl.sample([ch])
    assert len(tl) == 4 and tl.dropped == 2


def test_timeline_capacity_zero_disables_sampling():
    tl = TelemetryTimeline(capacity=0)
    assert tl.sample([_mk_channel()]) == 0 and len(tl) == 0


# ---------------------------------------------------------------------------
# YAML surface
# ---------------------------------------------------------------------------
def _yaml(scheduler="", inport_extra=""):
    return f"""
{scheduler}
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    inports:
      - filename: o.h5
        {inport_extra}
        dsets: [{{name: /g, memory: 1}}]
"""


def test_scheduler_block_parses_with_defaults():
    g = WorkflowGraph.from_yaml(_yaml())
    assert g.scheduler == SchedulerConfig()   # fifo, quantum 1, tick 4
    g2 = WorkflowGraph.from_yaml(
        _yaml(scheduler="scheduler: {policy: fair, quantum: 2, "
                        "tick_every: 3, telemetry: 16}"))
    assert g2.scheduler.policy == "fair" and g2.scheduler.quantum == 2
    assert g2.scheduler.tick_every == 3 and g2.scheduler.telemetry == 16


@pytest.mark.parametrize("block,msg", [
    ("scheduler: {policy: lifo}", "policy 'lifo' is invalid"),
    ("scheduler: {quantum: 0}", "quantum must be >= 1"),
    ("scheduler: {tick_every: 0}", "tick_every must be >= 1"),
    ("scheduler: {telemetry: -1}", "telemetry capacity must be >= 0"),
    ("scheduler: {bogus: 1}", "unknown keys"),
    ("scheduler: [fair]", "must be a mapping"),
])
def test_scheduler_block_rejects_bad_values(block, msg):
    with pytest.raises(ValueError, match=msg):
        WorkflowGraph.from_yaml(_yaml(scheduler=block))


def test_port_weight_and_autotune_parse_and_reach_channel():
    g = WorkflowGraph.from_yaml(
        _yaml(inport_extra="weight: 3\n        autotune: {min: 2, max: 5}"))
    inp = g.tasks["consumer"].inports[0]
    assert inp.weight == 3 and inp.autotune == (2, 5)
    w = Wilkins(g, {"producer": lambda: None, "consumer": lambda: None})
    (ch,) = w.channels
    assert ch.weight == 3 and ch.autotune == (2, 5)
    assert ch.prefetch == 2          # autotune implies prefetch: clamps to min
    assert ch.max_prefetch_depth == 5


def test_autotune_shorthand_spellings():
    g = WorkflowGraph.from_yaml(_yaml(inport_extra="autotune: 1"))
    assert g.tasks["consumer"].inports[0].autotune == (1, 8)
    g2 = WorkflowGraph.from_yaml(_yaml(inport_extra="autotune: 6"))
    assert g2.tasks["consumer"].inports[0].autotune == (1, 6)


@pytest.mark.parametrize("extra,msg", [
    ("weight: 0", "weight must be >= 1"),
    ("autotune: {min: 0, max: 4}", "autotune min must be >= 1"),
    ("autotune: {min: 3, max: 2}", "min <= max"),
    ("autotune: {max: 4, turbo: 1}", "unknown autotune keys"),
    ("autotune: {min: fast, max: 4}", "autotune min must be an integer"),
    ("autotune: {min: 1, max: 2.7}", "autotune max must be an integer"),
    ("autotune: 1\n        prefetch: 0", "autotune needs prefetch enabled"),
])
def test_port_knobs_reject_bad_values(extra, msg):
    with pytest.raises(ValueError, match=msg):
        WorkflowGraph.from_yaml(_yaml(inport_extra=extra))


def test_weight_and_autotune_rejected_on_outports():
    bad_weight = """
tasks:
  - func: producer
    outports:
      - filename: o.h5
        weight: 2
        dsets: [{name: /g, memory: 1}]
"""
    with pytest.raises(ValueError, match="weight is an inport declaration"):
        WorkflowGraph.from_yaml(bad_weight)
    with pytest.raises(ValueError, match="autotune is an inport declaration"):
        WorkflowGraph.from_yaml(bad_weight.replace("weight: 2", "autotune: 1"))


# ---------------------------------------------------------------------------
# runtime wiring (driver / vol / comm step hooks)
# ---------------------------------------------------------------------------
def _pipeline_yaml(steps_extra="", scheduler=""):
    return f"""
{scheduler}
tasks:
  - func: producer
    nprocs: 2
    outports:
      - filename: o.h5
        ownership: {{axis: 0}}
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    nprocs: 2
    inports:
      - filename: o.h5
        redistribute: 1
        {steps_extra}
        dsets: [{{name: /g, memory: 1}}]
"""


def _run_pipeline(yaml, steps=6):
    def producer():
        for _ in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(64.0))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            _ = f["/g"][0]

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    return w, w.run(timeout=60)


def test_run_report_carries_scheduler_snapshot_and_timeline():
    w, rep = _run_pipeline(_pipeline_yaml(
        scheduler="scheduler: {policy: fair, tick_every: 2}"))
    assert rep.scheduler["policy"] == "fair"
    assert rep.scheduler["steps"] >= 12       # closes + opens both count
    assert rep.scheduler["ticks"] >= 1
    assert rep.timeline is not None and len(rep.timeline) >= 1
    s = rep.summary()
    assert "scheduler: policy=fair" in s and "telemetry_samples=" in s
    # teardown: runtime detached from vols, channels detached from the pool
    assert all(v.scheduler is None for v in w.vols.values())
    assert w._sched_runtime is None


def test_run_default_policy_is_fifo_and_still_reports():
    _, rep = _run_pipeline(_pipeline_yaml())
    assert rep.scheduler["policy"] == "fifo"
    assert rep.scheduler["decisions"] == []
    assert rep.timeline is not None           # close() takes a final sample
    assert len(rep.timeline) >= 1
    # no scheduler: block and no autotuned edge -> the per-step VOL hooks
    # are NOT wired (legacy workflows pay zero per-step scheduler cost)
    assert rep.scheduler["steps"] == 0


def test_autotuned_edge_wires_step_hooks_without_scheduler_block():
    _, rep = _run_pipeline(_pipeline_yaml(steps_extra="autotune: 1"))
    assert rep.scheduler["policy"] == "fifo"
    assert rep.scheduler["steps"] > 0         # hooks wired for the autotuner


def test_comm_step_feeds_the_runtime():
    cfg = SchedulerConfig(tick_every=2, telemetry=8)
    ch = _mk_channel(prefetch=1)
    rt = SchedulerRuntime(cfg, [ch])
    from repro.core.comm import TaskComm
    comm = TaskComm(task="t", scheduler=rt)
    for _ in range(4):
        comm.step()
    assert rt.steps == 4
    assert rt.snapshot()["step_sources"] == {"comm_step": 4}
    assert len(rt.timeline) == 2              # a tick every 2 steps
    rt.close()
    assert len(rt.timeline) == 3              # final sample
    comm.step()                               # closed: ignored, no tick
    assert rt.steps == 4


def test_error_report_still_carries_scheduler_state():
    yaml = _pipeline_yaml(scheduler="scheduler: {policy: fair}")

    def producer():
        raise RuntimeError("boom")

    def consumer():
        while h5.File("o.h5", "r") is not None:
            pass

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    with pytest.raises(RuntimeError, match="boom") as ei:
        w.run(timeout=60)
    rep = ei.value.report
    assert rep.scheduler["policy"] == "fair"
    assert rep.timeline is not None


# ---------------------------------------------------------------------------
# fairness + convergence under real threads (slow)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fair_weights_shift_prep_completions_disparate_rates():
    """2-edge disparate-rate contention on a 1-worker pool: weights 3:1
    shift prep completions toward the heavy edge by >= 2:1 while both edges
    stay backlogged."""
    done = {"hot": 0, "cold": 0}
    window = []
    lock = threading.Lock()
    pool = PrefetchPool(max_workers=1, policy=FairPolicy())
    gate = threading.Event()
    pool.submit(gate.wait)          # park the worker; queues build behind it
    futs = []
    for i in range(12):
        for edge, wgt in (("hot", 3), ("cold", 1)):
            def prep(edge=edge):
                with lock:
                    done[edge] += 1
                    if len(window) < 12:
                        window.append(edge)
            futs.append(pool.submit(prep, edge=edge, weight=wgt))
    gate.set()
    for f in futs:
        f.result(timeout=10)
    hot = window.count("hot")
    cold = window.count("cold")
    assert hot >= 2 * cold, f"completion window {window}"
    assert done == {"hot": 12, "cold": 12}   # everything still completes
    pool.shutdown()


@pytest.mark.slow
def test_autotuner_raises_depth_on_blocked_edge_in_real_workflow():
    """Fast producer -> slow-prep edge under autotune: the depth rises from
    its floor within the bound."""
    yaml = _pipeline_yaml(
        steps_extra="prefetch: 1\n        queue_depth: 4\n        "
                    "autotune: {min: 1, max: 4}",
        scheduler="scheduler: {policy: fair, tick_every: 2}")

    def producer():
        for _ in range(12):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(4096.0))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            _ = f["/g"][0]

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    (ch,) = w.channels
    orig = ch._prepare

    def slow_prepare(*a, **kw):
        time.sleep(0.03)            # slower than the consumer: misses pile up
        return orig(*a, **kw)

    ch._prepare = slow_prepare
    rep = w.run(timeout=120)
    grew = [d for d in rep.scheduler["decisions"] if "grow" in d["reason"]]
    assert grew, rep.scheduler["decisions"]
    assert 1 < rep.scheduler["depths"][ch.name] <= 4
