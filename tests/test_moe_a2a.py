"""Explicit-collective MoE (shard_map EP schedule) vs the dense oracle.

The multi-device check runs in a subprocess so the 8 virtual host devices
don't leak into the rest of the suite (jax locks device count at init).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=2, d_model=32, vocab=64,
                n_heads=2, n_kv_heads=2, d_ff=64, n_experts=8, top_k=2,
                moe_d_ff=64, dtype="float32", capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


def test_a2a_unavailable_without_mesh_falls_back():
    cfg = _cfg(moe_dispatch="a2a")
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 32)),
                    jnp.float32) * 0.1
    out, aux = L.moe(p, cfg, x)          # no mesh -> sorted/dense fallback
    want, aux_w = L.moe_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.models import layers as L
    from repro.models.config import ModelConfig
    from repro.parallel.sharding import RULE_VARIANTS, use_mesh

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      vocab=64, n_heads=2, n_kv_heads=2, d_ff=64,
                      n_experts=8, top_k=2, moe_d_ff=64, dtype="float32",
                      capacity_factor=8.0, moe_dispatch="a2a")
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 16, 32)),
                    jnp.float32) * 0.1
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    rules = RULE_VARIANTS["moe_a2a"]
    want, _ = L.moe_dense(p, cfg, x)
    with use_mesh(mesh, rules):
        got, _ = jax.jit(lambda p, x: L.moe(p, cfg, x))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)

    def loss_a2a(p, x):
        with use_mesh(mesh, rules):
            y, _ = L.moe(p, cfg, x)
        return jnp.sum(y ** 2)

    def loss_dense(p, x):
        y, _ = L.moe_dense(p, cfg, x)
        return jnp.sum(y ** 2)

    with use_mesh(mesh, rules):
        g1 = jax.jit(jax.grad(loss_a2a))(p, x)
    g2 = jax.grad(loss_dense)(p, x)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   atol=5e-4, rtol=5e-3, err_msg=k)
    print("A2A_OK")
""")


@pytest.mark.slow
def test_a2a_matches_oracle_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MULTIDEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "A2A_OK" in out.stdout
