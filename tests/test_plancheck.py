"""Tier-1 tests for the reshard-plan coverage verifier (satellite 1):
``repro.analysis.plancheck`` -- WLK225 exactly-once coverage and WLK226
bounds over compiled M->N redistribution plans.

Three layers: direct ``verify_plan``/``verify_edge`` unit tests over
hand-corrupted plans, the seeded runtime fixtures, and a property test
(hypothesis, skipped when absent) asserting the planner's own output
always verifies clean.
"""

import dataclasses
import importlib.util
import os

import pytest

from hypcompat import HAVE_HYPOTHESIS, given, settings, st
from repro.analysis import plancheck
from repro.core.redistribute import CompiledPlan, even_blocks

HERE = os.path.dirname(os.path.abspath(__file__))
RUNDIR = os.path.join(HERE, "analysis_fixtures", "runtime")


def _codes(findings):
    return sorted(d.code for d in findings)


def _plan(shape, m, n, axis=0):
    return CompiledPlan(even_blocks(shape, m, axis=axis),
                        even_blocks(shape, n, axis=axis), shape)


# ---------------------------------------------------------------------------
# verify_plan: clean plans verify clean
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,m,n,axis", [
    ((12, 8), 3, 2, 0),
    ((12, 8), 2, 5, 0),
    ((7,), 3, 4, 0),          # ragged 1-D
    ((6, 10), 4, 3, 1),       # column axis
    ((5, 5), 1, 1, 0),        # identity
    ((4, 4, 4), 2, 3, 2),     # 3-D
])
def test_planner_output_verifies_clean(shape, m, n, axis):
    out = plancheck.verify_plan(_plan(shape, m, n, axis=axis))
    assert not list(out), out.render_text()


def test_verify_edge_clean_and_context():
    out = plancheck.verify_edge((12, 8), 0, 3, 2, context="edge a->b")
    assert not list(out)


# ---------------------------------------------------------------------------
# verify_plan: seeded defects produce the right codes
# ---------------------------------------------------------------------------
def test_dropped_transfer_is_a_coverage_hole():
    plan = _plan((12, 8), 3, 2)
    victim = plan.per_dst[0]
    assert len(victim) > 1, "scenario needs a multi-source dst rank"
    object.__setattr__(plan, "per_dst", (victim[1:],) + plan.per_dst[1:])
    out = plancheck.verify_plan(plan, context="dropped transfer")
    assert "WLK225" in _codes(out)
    assert any("never written" in d.message for d in out)
    assert all(d.message.startswith("dropped transfer: ") for d in out)


def test_duplicated_transfer_is_written_twice():
    plan = _plan((12, 8), 3, 2)
    dup = plan.per_dst[0]
    object.__setattr__(plan, "per_dst", (dup + dup[:1],) + plan.per_dst[1:])
    out = plancheck.verify_plan(plan)
    assert "WLK225" in _codes(out)
    assert any("written twice" in d.message for d in out)


def test_shifted_transfer_escapes_extent():
    plan = _plan((12, 8), 2, 2)
    t = plan.per_dst[1][0]
    bad = dataclasses.replace(
        t, global_starts=(plan.shape[0] - t.shape[0] + 1, 0))
    object.__setattr__(plan, "per_dst",
                       (plan.per_dst[0], (bad,) + plan.per_dst[1][1:]))
    out = plancheck.verify_plan(plan)
    assert "WLK226" in _codes(out)
    assert any("out of bounds" in d.message for d in out)


def test_transfer_escaping_its_dst_block_is_flagged():
    # in bounds globally, but lands in the WRONG rank's block
    plan = _plan((12, 8), 2, 2)
    t = plan.per_dst[1][0]
    bad = dataclasses.replace(t, global_starts=(0, 0))
    object.__setattr__(plan, "per_dst",
                       (plan.per_dst[0], (bad,) + plan.per_dst[1][1:]))
    out = plancheck.verify_plan(plan)
    assert "WLK226" in _codes(out)
    assert any("escapes the destination block" in d.message for d in out)


def test_corrupt_dst_box_is_out_of_bounds():
    plan = _plan((12, 8), 2, 2)
    (s0, sh0), _ = plan.dst
    object.__setattr__(plan, "dst", ((s0, (sh0[0] + 99, sh0[1])), plan.dst[1]))
    out = plancheck.verify_plan(plan)
    assert "WLK226" in _codes(out)
    assert any("dst rank 0 block" in d.message for d in out)


# ---------------------------------------------------------------------------
# the seeded runtime fixtures trigger end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stem,code", [
    ("wlk225_plan_coverage", "WLK225"),
    ("wlk226_plan_bounds", "WLK226"),
])
def test_runtime_fixture_triggers(stem, code):
    path = os.path.join(RUNDIR, stem + ".py")
    spec = importlib.util.spec_from_file_location("_pc_" + stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert code in _codes(mod.trigger())


# ---------------------------------------------------------------------------
# property: every planner-generated (shape, axis, M, N) edge verifies clean
# ---------------------------------------------------------------------------
@settings(max_examples=80, deadline=None)
@given(st.data())
def test_every_planned_edge_verifies_clean(data):
    ndim = data.draw(st.integers(min_value=1, max_value=3), label="ndim")
    shape = tuple(data.draw(
        st.lists(st.integers(min_value=1, max_value=24),
                 min_size=ndim, max_size=ndim), label="shape"))
    axis = data.draw(st.integers(min_value=0, max_value=ndim - 1),
                     label="axis")
    m = data.draw(st.integers(min_value=1, max_value=8), label="src_nranks")
    n = data.draw(st.integers(min_value=1, max_value=8), label="dst_nranks")
    out = plancheck.verify_edge(shape, axis, m, n,
                                context=f"{shape}/{axis} {m}->{n}")
    assert not list(out), out.render_text()


def test_hypothesis_availability_is_reported():
    # keep the skip visible: when the image gains hypothesis the property
    # test above starts running instead of silently staying skipped
    assert HAVE_HYPOTHESIS in (True, False)
