"""Shared pytest hooks.

When a shard runs under ``WILKINS_LOCKCHECK=1`` (see ``repro.analysis.
lockcheck``) every lock the core constructs is a checked wrapper recording
the cross-thread acquisition graph.  At session end we fail the run if the
recorder saw a lock-order cycle, a rank inversion, or a blocking call under
a fine-grained lock -- even if every individual test passed.
"""

import os
import sys


def pytest_sessionfinish(session, exitstatus):
    if os.environ.get("WILKINS_LOCKCHECK", "") in ("", "0"):
        return
    from repro.analysis.lockcheck import registry
    findings = registry().findings()
    if findings.errors():
        print("\nWILKINS_LOCKCHECK: lock-discipline violations recorded:",
              file=sys.stderr)
        print(findings.render_text(), file=sys.stderr)
        session.exitstatus = 1
