"""Serving engine: continuous batching, determinism, weight hot-swap."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import Engine, Request, ServeConfig

CFG = get_config("tinyllama-1.1b", reduced=True)


def _reqs(n, rng, max_new=5):
    return [Request(rid=i, prompt=rng.integers(0, CFG.vocab, 7, dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_drains_more_requests_than_slots():
    eng = Engine(CFG, ServeConfig(max_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    reqs = _reqs(5, rng)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)


def test_greedy_is_deterministic():
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, CFG.vocab, 7, dtype=np.int32)
    outs = []
    for _ in range(2):
        eng = Engine(CFG, ServeConfig(max_slots=1, max_len=64),
                     key=jax.random.PRNGKey(3))
        r = Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)
        eng.submit(r)
        eng.run_until_drained()
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]


def test_batching_invariance():
    """A request's tokens don't depend on what shares the batch."""
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, CFG.vocab, 7, dtype=np.int32)

    eng1 = Engine(CFG, ServeConfig(max_slots=1, max_len=64),
                  key=jax.random.PRNGKey(5))
    alone = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
    eng1.submit(alone)
    eng1.run_until_drained()

    eng2 = Engine(CFG, ServeConfig(max_slots=3, max_len=64),
                  key=jax.random.PRNGKey(5))
    shared = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
    eng2.submit(shared)
    for r in _reqs(2, rng, max_new=4):
        r.rid += 10
        eng2.submit(r)
    eng2.run_until_drained()
    assert alone.out_tokens == shared.out_tokens


def test_weight_hot_swap_changes_output():
    """In-situ checkpoint consumption: new weights -> new behaviour."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, CFG.vocab, 7, dtype=np.int32)
    eng = Engine(CFG, ServeConfig(max_slots=1, max_len=64),
                 key=jax.random.PRNGKey(0))
    r1 = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
    eng.submit(r1)
    eng.run_until_drained()

    from repro.models.registry import get_family
    eng.swap_params(get_family(CFG).init(jax.random.PRNGKey(99), CFG))
    r2 = Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)
    eng.submit(r2)
    eng.run_until_drained()
    assert r1.out_tokens != r2.out_tokens
