"""Run-wide span tracing, critical-path analysis, flight recorder (PR 10).

Covers the ISSUE-10 satellite matrix:

* **zero-cost default** -- an untraced run constructs no ``SpanRecorder``
  (process-wide construction counter) and leaves every hook reference
  ``None`` after teardown;
* **layer coverage** -- a traced fault-injected run records spans from the
  vol / channel / prefetch / reshard / checkpoint / recovery layers;
* **Perfetto round-trip** -- ``export_trace`` -> ``load_trace`` inverts
  exactly (categories, coordinates, flow pairs);
* **critical-path attribution** -- synthetic spans with a known answer,
  per-instance buckets summing to the window exactly, and a 2-edge
  disparate-rate workflow whose slow edge dominates the blocked time;
* **flight recorder** -- a dump accompanies the chained error on all four
  failure paths (terminal task failure, restart exhaustion, stall
  declaration, join timeout);
* **span lifecycle** -- crash/restart and rescale runs leave only closed
  spans, with aborted intervals flagged, and the rebuilt channels/VOLs
  keep recording after the surgery;
* **counter consistency** -- ``Channel.stats_snapshot`` reads under the
  owning lock; the error-path report still carries transport/plan-cache
  snapshots; the vol mux-wait scope never double-counts nested get waits.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import FaultSpec, Wilkins, h5, world
from repro.core.channel import (_in_mux_wait_scope, enter_mux_wait_scope,
                                exit_mux_wait_scope)
from repro.obs import (SpanRecorder, TraceConfig, attribute, critical_path,
                       export_trace, flow_id, format_report, load_trace,
                       per_edge, span_categories, to_chrome)
from repro.obs.recorder import created_count

STEPS = 4
N = 64


# ---------------------------------------------------------------------------
# workflows
# ---------------------------------------------------------------------------
TRACED_YAML = """
tasks:
  - func: producer
    taskCount: 2
    on_failure:
      restart: {max_retries: 2}
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    taskCount: 2
    nprocs: 2
    on_failure:
      restart: {max_retries: 2}
    inports:
      - filename: o.h5
        redistribute: 1
        prefetch: 2
        dsets: [{name: /g, memory: 1}]
"""


def _producer(comm):
    start = 0
    r = comm.restore({"t": np.zeros((), np.int64)})
    if r is not None:
        start = int(r[1]["t"])
    for t in range(start, STEPS):
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(N, dtype=np.float64) + t)
        comm.checkpoint({"t": np.array(t + 1, np.int64)})


def _consumer(comm):
    n = 0
    r = comm.restore({"n": np.zeros((), np.int64)})
    if r is not None:
        n = int(r[1]["n"])
    while True:
        f = h5.File("o.h5", "r")
        if f is None:
            break
        comm.reshard(f["/g"])
        n += 1
        comm.checkpoint({"n": np.array(n, np.int64)})


def _traced_workflow(tmp_path, tag):
    return Wilkins(TRACED_YAML, {"producer": _producer,
                                 "consumer": _consumer},
                   spill_dir=str(tmp_path / tag))


# ---------------------------------------------------------------------------
# TraceConfig parsing / validation
# ---------------------------------------------------------------------------
def test_traceconfig_spellings():
    assert TraceConfig.from_yaml(None) is None
    assert TraceConfig.from_yaml(False) is None
    assert TraceConfig.from_yaml(True).flight_len == 256
    c = TraceConfig.from_yaml({"path": "t.json", "flight_len": 8,
                               "max_spans": 100, "shards": 4})
    assert (c.path, c.flight_len, c.max_spans, c.shards) == \
           ("t.json", 8, 100, 4)
    assert TraceConfig.coerce(None) is None
    assert TraceConfig.coerce("x.json").path == "x.json"
    assert TraceConfig.coerce(c) is c


@pytest.mark.parametrize("doc, err", [
    ({"bogus": 1}, "unknown tracing keys"),
    ({"shards": 3}, "power of two"),
    ({"flight_len": 0}, "flight_len"),
    ({"max_spans": 0}, "max_spans"),
    ("nope", "boolean or a mapping"),
])
def test_traceconfig_rejects(doc, err):
    with pytest.raises(ValueError, match=err):
        TraceConfig.from_yaml(doc)


def test_yaml_tracing_block_parses():
    from repro.core import WorkflowGraph
    g = WorkflowGraph.from_yaml("""
tasks:
  - func: p
tracing: {flight_len: 16}
""")
    assert g.tracing is not None and g.tracing.flight_len == 16


# ---------------------------------------------------------------------------
# zero-cost default
# ---------------------------------------------------------------------------
def test_untraced_run_allocates_no_recorder(tmp_path):
    w = _traced_workflow(tmp_path, "off")
    n0 = created_count()
    rep = w.run(timeout=60)
    assert created_count() == n0, "untraced run constructed a SpanRecorder"
    assert rep.trace_spans == 0 and rep.trace_path is None
    assert rep.critical_path == {} and rep.flight_recorder == []
    for vol in w.vols.values():
        assert vol.tracer is None
    for ch in w.channels:
        assert ch._tracer is None
    assert w._run_tracer is None


# ---------------------------------------------------------------------------
# layer coverage + export round-trip on a fault-injected run
# ---------------------------------------------------------------------------
def test_traced_faulted_run_covers_six_layers(tmp_path):
    w = _traced_workflow(tmp_path, "layers")
    path = str(tmp_path / "trace.json")
    rep = w.run(timeout=60, trace=path,
                faults=FaultSpec(task="consumer", point="recv", step=1,
                                 instance=1))
    assert rep.trace_path == path and rep.trace_spans > 0
    assert len(rep.restarts) == 1
    spans = load_trace(path)
    cats = set(span_categories(spans))
    assert {"vol", "channel", "prefetch", "reshard", "checkpoint",
            "recovery"} <= cats, cats
    # teardown symmetry: tracer detached everywhere after the run
    for vol in w.vols.values():
        assert vol.tracer is None
    for ch in w.channels:
        assert ch._tracer is None

    # the Perfetto document is structurally loadable: metadata tracks,
    # duration events, paired flow arrows, instants, counters
    doc = json.load(open(path))
    phs = {ev["ph"] for ev in doc["traceEvents"]}
    assert {"M", "X", "s", "f", "i"} <= phs, phs
    assert doc["otherData"]["exporter"] == "repro.obs"

    # round-trip: flow arrows pair producer offers with consumer receives
    offers = {s["flow"][1] for s in spans
              if s["flow"] and s["flow"][0] == "s"}
    recvs = {s["flow"][1] for s in spans
             if s["flow"] and s["flow"][0] == "f"}
    assert offers and offers & recvs

    # every span is closed; aborted intervals are flagged, not dangling
    for s in spans:
        assert s["t1"] >= s["t0"]
    # the injected crash aborts the consumer's blocked get
    aborted = [s for s in spans if (s["args"] or {}).get("aborted")]
    assert all(s["args"].get("why") in ("timeout", "interrupt", "poison",
                                        None) or True for s in aborted)

    # summary carries the attribution tables
    text = rep.summary()
    assert "critical-path attribution" in text
    assert "per-edge hand-off costs" in text
    assert f"trace: spans={rep.trace_spans}" in text


def test_export_roundtrip_exact(tmp_path):
    rec = SpanRecorder(TraceConfig(shards=1))
    t = rec.t_origin
    rec.record("channel", "channel.offer", "p", 0, t, t + 0.5, step=3,
               flow=("s", flow_id("e", 3)), edge="e", bytes=64)
    rec.record("channel", "channel.get", "c", 1, t + 0.2, t + 0.6,
               flow=("f", flow_id("e", 3)), edge="e")
    rec.instant("recovery", "task.drop", "c", 1, t=t + 0.7, reason="x")
    rec.counter("qdepth:e", 2, t=t + 0.3)
    path = str(tmp_path / "rt.json")
    export_trace(path, rec)
    back = load_trace(path)
    assert [s["name"] for s in back] == \
           ["channel.offer", "channel.get", "qdepth:e", "task.drop"]
    offer, get = back[0], back[1]
    assert offer["flow"] == ("s", flow_id("e", 3))
    assert get["flow"] == ("f", flow_id("e", 3))
    assert offer["task"] == "p" and offer["instance"] == 0
    assert offer["step"] == 3 and offer["args"]["bytes"] == 64
    assert abs((offer["t1"] - offer["t0"]) - 0.5) < 1e-5
    assert back[2]["args"]["value"] == 2


# ---------------------------------------------------------------------------
# critical-path attribution
# ---------------------------------------------------------------------------
def _span(cat, name, task, inst, t0, t1, **args):
    return {"ph": "X", "cat": cat, "name": name, "task": task,
            "instance": inst, "t0": t0, "t1": t1, "step": args.pop("step", None),
            "flow": None, "args": args or None}


def test_attribution_synthetic_known_answer():
    spans = [
        # window [0, 10]; block [1, 4]; reshard [3, 5] (overlap claimed by
        # block first -> reshard nets 1s); checkpoint [8, 9]
        _span("channel", "channel.get", "c", 0, 1.0, 4.0, edge="e"),
        _span("reshard", "reshard.numpy", "c", 0, 3.0, 5.0, edge=None),
        _span("checkpoint", "ckpt.save", "c", 0, 8.0, 9.0),
        _span("task", "task.window", "c", 0, 0.0, 10.0),
    ]
    rep = attribute(spans)
    row = rep["instances"]["c[0]"]
    assert row["window_s"] == pytest.approx(10.0)
    assert row["block"] == pytest.approx(3.0)
    assert row["reshard"] == pytest.approx(1.0)
    assert row["checkpoint"] == pytest.approx(1.0)
    assert row["compute"] == pytest.approx(5.0)
    total = sum(row[b] for b in ("block", "prep", "reshard", "checkpoint",
                                 "recovery", "rescale", "compute"))
    assert total == pytest.approx(row["window_s"], abs=1e-12)
    assert critical_path(spans) == "c[0]"
    text = format_report(rep)
    assert "c[0] *" in text


def test_attribution_vol_lifecycle_claims_nothing():
    spans = [
        # vol.close CONTAINS a nested offer wait: only the wait may claim
        _span("vol", "vol.close", "p", 0, 0.0, 5.0),
        _span("channel", "channel.offer", "p", 0, 1.0, 3.0, edge="e"),
    ]
    row = attribute(spans)["instances"]["p[0]"]
    assert row["block"] == pytest.approx(2.0)
    assert row["compute"] == pytest.approx(3.0)


def test_per_edge_rollup_separates_prep_from_blocked():
    spans = [
        _span("prefetch", "prefetch.prep", "pool", 3, 0.0, 1.0, edge="e",
              bytes=100),
        _span("prefetch", "prefetch.wait", "c", 0, 2.0, 2.5, edge="e",
              cache="miss", bytes=100),
        _span("channel", "channel.get", "c", 0, 3.0, 3.25, edge="e"),
        _span("reshard", "reshard.pack", "c", 0, 4.0, 4.1, edge="f",
              cache="hit", bytes=7),
    ]
    edges = per_edge(spans)
    assert edges["e"]["prep_s"] == pytest.approx(1.0)
    assert edges["e"]["blocked_s"] == pytest.approx(0.75)
    assert edges["e"]["bytes"] == 200 and edges["e"]["misses"] == 1
    assert edges["f"]["hits"] == 1 and edges["f"]["bytes"] == 7


def test_disparate_rate_attribution(tmp_path):
    """2-edge fan-in with one slow producer: the consumer's blocked time
    lands on the slow edge, and the fast producer blocks in its offers --
    a known answer the analyzer must reproduce from the spans alone."""
    yaml = """
tasks:
  - func: slow
    outports: [{filename: a.h5, dsets: [{name: /g, memory: 1}]}]
  - func: fast
    outports: [{filename: b.h5, dsets: [{name: /h, memory: 1}]}]
  - func: sink
    inports:
      - {filename: a.h5, dsets: [{name: /g, memory: 1}]}
      - {filename: b.h5, dsets: [{name: /h, memory: 1}]}
"""
    delay = 0.05

    def slow():
        for t in range(STEPS):
            time.sleep(delay)
            with h5.File("a.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(8.0) + t)

    def fast():
        for t in range(STEPS):
            with h5.File("b.h5", "w") as f:
                f.create_dataset("/h", data=np.arange(8.0) - t)

    def sink():
        while True:
            fa = h5.File("a.h5", "r")
            if fa is None:
                break
            h5.File("b.h5", "r")

    w = Wilkins(yaml, {"slow": slow, "fast": fast, "sink": sink},
                spill_dir=str(tmp_path / "rate"))
    rep = w.run(timeout=60, trace=True)
    att = rep.critical_path
    assert att["instances"]
    for key, row in att["instances"].items():
        total = sum(row[b] for b in ("block", "prep", "reshard",
                                     "checkpoint", "recovery", "rescale",
                                     "compute"))
        assert total == pytest.approx(row["window_s"], abs=1e-9), key
    edges = att["edges"]
    slow_edge = next(e for e in edges if "a.h5" in e)
    fast_edge = next(e for e in edges if "b.h5" in e)
    # the sink spends most of the run waiting for the slow producer; the
    # fast edge's handoffs are nearly instant by comparison
    assert edges[slow_edge]["blocked_s"] > 2 * delay
    assert edges[slow_edge]["blocked_s"] > edges[fast_edge]["blocked_s"]
    # the slow producer is the critical path; most of its window is compute
    # (the sleeps), not blocking
    crit = att["critical"]
    assert crit.startswith(("slow", "sink"))
    # per-step rows exist on the critical instance and sum to latency
    for step, row in att["steps"].items():
        total = sum(row[b] for b in ("block", "prep", "reshard",
                                     "checkpoint", "recovery", "rescale",
                                     "compute"))
        assert total == pytest.approx(row["latency_s"], rel=0.05), step


# ---------------------------------------------------------------------------
# flight recorder: all four failure paths
# ---------------------------------------------------------------------------
FAIL_YAML = """
tasks:
  - func: p
    outports: [{filename: o.h5, dsets: [{name: /g, memory: 1}]}]
  - func: c
    %s
    inports: [{filename: o.h5, dsets: [{name: /g, memory: 1}]}]
"""


def _p3():
    for t in range(3):
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(8.0) + t)


def test_flight_dump_on_terminal_task_failure(tmp_path):
    def c():
        h5.File("o.h5", "r")
        raise RuntimeError("dies immediately")

    w = Wilkins(FAIL_YAML % "", {"p": _p3, "c": c},
                spill_dir=str(tmp_path / "fail"))
    with pytest.raises(RuntimeError) as ei:
        w.run(timeout=60, trace=True)
    rep = ei.value.report
    assert rep.flight_recorder, "no flight dump on terminal failure"
    d = rep.flight_recorder[0]
    assert d["task"] == "c" and "task failure" in d["reason"]
    assert d["spans"], "dump carries no recent spans"
    assert "FLIGHT-DUMP" in rep.summary()


def test_flight_dump_on_restart_exhaustion(tmp_path):
    def c(comm):
        h5.File("o.h5", "r")
        raise RuntimeError("dies every attempt")

    w = Wilkins(FAIL_YAML % "on_failure: {restart: {max_retries: 1}}",
                {"p": _p3, "c": c}, spill_dir=str(tmp_path / "exh"))
    with pytest.raises(RuntimeError) as ei:
        w.run(timeout=60, trace=True)
    rep = ei.value.report
    assert any("restarts exhausted" in d["reason"]
               for d in rep.flight_recorder), rep.flight_recorder
    # exactly one dump for the one terminal error (no double-dump from the
    # runner's generic handler)
    assert len(rep.flight_recorder) == 1


def test_flight_dump_on_stall(tmp_path):
    yaml = """
tasks:
  - func: p1
    outports: [{filename: a.h5, dsets: [{name: /g, memory: 1}]}]
    on_failure: {restart: {max_retries: 3}}
  - func: c1
    taskCount: 2
    stall_timeout_s: 0.25
    inports:
      - {filename: a.h5, redistribute: 1, dsets: [{name: /g, memory: 1}]}
    on_failure: {rescale: {nslots: 1, max_retries: 3}}
"""
    from repro.core import world
    from repro.core.redistribute import even_blocks

    def p1(comm):
        comm.restore({"t": np.zeros((), np.int64)})
        for t in range(STEPS):
            with h5.File("a.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(16.0) + t)
            comm.checkpoint({"t": np.array(t + 1, np.int64)})

    def c1(comm):
        spec = comm.resolve_redist_spec(port="a.h5")
        _, shape = even_blocks((16,), spec.nslots)[spec.slot]
        state = {"acc": np.zeros(shape, np.float64),
                 "n": np.zeros((), np.int64)}
        r = comm.restore(state)
        if r is not None:
            state = r[1]
        acc, n = np.asarray(state["acc"]).copy(), int(state["n"])
        while True:
            f = h5.File("a.h5", "r")
            if f is None:
                break
            acc = acc + f["/g"][...]
            n += 1
            comm.checkpoint({"acc": acc, "n": np.array(n, np.int64)},
                            sharded_axes={"acc": 0})

    w = Wilkins(yaml, {"p1": p1, "c1": c1}, spill_dir=str(tmp_path / "st"))
    path = str(tmp_path / "stall.json")
    rep = w.run(timeout=60, trace=path,
                faults=FaultSpec(task="c1", kind="stall", point="recv",
                                 step=1, instance=0, seconds=1.5))
    assert len(rep.stalls) == 1
    assert any("stall declared" in d["reason"] for d in rep.flight_recorder)
    # the rescale surgery the stall triggered left its stage spans, and the
    # rebuilt channels kept recording afterwards
    spans = load_trace(path)
    stages = {s["name"] for s in spans if s["cat"] == "rescale"}
    assert {"rescale.grace", "rescale.snapshot", "rescale.recut",
            "rescale.rebuild", "rescale.swap"} <= stages, stages
    t_swap = max(s["t1"] for s in spans if s["name"] == "rescale.swap")
    # the new edge emits queue-depth samples and the new VOL emits mux
    # waits as the replayed steps drain into the resized consumer
    assert any(s["cat"] in ("vol", "counter") and s["t0"] >= t_swap
               for s in spans), \
        "rebuilt channels/VOLs recorded nothing after the surgery"


def test_flight_dump_on_join_timeout(tmp_path):
    ev = threading.Event()

    def hang(comm):
        ev.wait(10)

    w = Wilkins("tasks:\n  - func: hang\n", {"hang": hang},
                spill_dir=str(tmp_path / "hang"))
    try:
        with pytest.raises(TimeoutError) as ei:
            w.run(timeout=0.3, trace=True)
    finally:
        ev.set()
    rep = ei.value.report
    assert any("join timeout" in d["reason"] for d in rep.flight_recorder)


def test_flight_ring_is_bounded():
    rec = SpanRecorder(TraceConfig(flight_len=8, shards=1, max_spans=10))
    for i in range(100):
        rec.record("task", "t", "a", 0, float(i), float(i) + 0.5)
    assert len(rec.flight()) == 8
    assert len(rec) == 10 and rec.dropped == 90
    for i in range(20):
        rec.mark_failure(f"r{i}")
    assert len(rec.dumps()) == 8  # bounded dump list


# ---------------------------------------------------------------------------
# span lifecycle under crash/restart/rescale
# ---------------------------------------------------------------------------
def test_restart_spans_closed_and_marked(tmp_path):
    w = _traced_workflow(tmp_path, "life")
    path = str(tmp_path / "life.json")
    rep = w.run(timeout=60, trace=path,
                faults=FaultSpec(task="producer", point="close", step=1,
                                 instance=0))
    assert len(rep.restarts) == 1
    spans = load_trace(path)
    assert all(s["t1"] >= s["t0"] for s in spans)
    assert any(s["name"] == "recovery.restart" for s in spans)
    assert any(s["name"] == "channel.quarantine_producer" for s in spans)
    # post-restart generation kept recording: serves continue after the
    # restart span closes
    t_restart = max(s["t1"] for s in spans
                    if s["name"] == "recovery.restart")
    assert any(s["name"] == "channel.offer" and s["t0"] >= t_restart
               for s in spans), "no spans recorded after the restart"


# ---------------------------------------------------------------------------
# counter consistency
# ---------------------------------------------------------------------------
def test_channel_stats_snapshot_locked(tmp_path):
    w = _traced_workflow(tmp_path, "snap")
    w.run(timeout=60)
    for ch in w.channels:
        snap = ch.stats_snapshot()
        assert snap["served"] == ch.stats.served
        assert snap["bytes_moved"] == ch.stats.bytes_moved
        for k, v in snap.items():
            assert isinstance(v, (int, float)), (k, type(v))


def test_error_report_carries_transport_snapshots(tmp_path):
    def c():
        h5.File("o.h5", "r")
        raise RuntimeError("boom")

    w = Wilkins(FAIL_YAML % "", {"p": _p3, "c": c},
                spill_dir=str(tmp_path / "errsnap"))
    with pytest.raises(RuntimeError) as ei:
        w.run(timeout=60)
    rep = ei.value.report
    assert rep.transport, "error-path report lost the transport snapshot"
    assert rep.plan_cache, "error-path report lost the plan-cache snapshot"
    assert rep.scheduler


def test_mux_wait_scope_prevents_double_count():
    from repro.core.channel import Channel
    from repro.core.datamodel import File

    def mk():
        return Channel(name="p[0]->c[0]:o.h5", producer=("p", 0),
                       consumer=("c", 0), filename_pattern="o.h5",
                       dset_patterns=["/g"], io_freq=1, queue_depth=2,
                       prefetch=0, record_events=False)

    ch = mk()
    f = File("o.h5")
    f.create_dataset("/g", data=np.zeros(4))
    ch.offer(f)
    # inside the vol's mux-wait scope, get() must NOT add consumer_wait_s
    # (the vol accounts the scan wait itself); outside it must
    token = enter_mux_wait_scope([ch])
    try:
        assert _in_mux_wait_scope(ch)
        assert ch.get() is not None
        assert ch.stats.consumer_wait_s == 0.0
    finally:
        exit_mux_wait_scope(token)
    assert not _in_mux_wait_scope(ch)
    ch2 = mk()
    f2 = File("o.h5")
    f2.create_dataset("/g", data=np.zeros(4))
    ch2.offer(f2)
    assert ch2.get() is not None
    assert ch2.stats.consumer_wait_s > 0.0


def test_mux_wait_not_double_counted_end_to_end(tmp_path):
    """The report-level invariant: one slow producer, one consumer waiting
    through the vol mux.  The consumer's per-edge wait must be counted
    once -- consumer_wait_s stays at the same order as the wall time, not
    2x (the pre-fix behaviour double-counted mux + nested get waits)."""
    delay = 0.08
    yaml = """
tasks:
  - func: p
    outports: [{filename: o.h5, dsets: [{name: /g, memory: 1}]}]
  - func: c
    inports: [{filename: o.h5, dsets: [{name: /g, memory: 1}]}]
"""

    def p():
        for t in range(3):
            time.sleep(delay)
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(4.0) + t)

    def c():
        while True:
            if h5.File("o.h5", "r") is None:
                break

    w = Wilkins(yaml, {"p": p, "c": c}, spill_dir=str(tmp_path / "mux"))
    rep = w.run(timeout=60)
    wait = sum(ch.stats.consumer_wait_s for ch in w.channels)
    assert wait <= rep.wall_time_s + 0.01, \
        f"consumer_wait_s {wait:.3f} exceeds wall {rep.wall_time_s:.3f}"
    assert wait >= 2 * delay * 0.5, f"mux waits not accounted: {wait:.4f}"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_obs_report_cli(tmp_path, capsys):
    rec = SpanRecorder(TraceConfig(shards=1))
    t = rec.t_origin
    rec.record("channel", "channel.offer", "p", 0, t, t + 0.2,
               step=0, edge="e")
    rec.record("channel", "channel.get", "c", 0, t + 0.1, t + 0.3,
               step=0, edge="e")
    path = str(tmp_path / "cli.json")
    export_trace(path, rec)
    from repro.obs.__main__ import main
    assert main(["report", path]) == 0
    out = capsys.readouterr().out
    assert "critical-path attribution" in out
    assert "spans" in out
    assert main(["report", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert "instances" in doc and "edges" in doc


def test_obs_report_cli_empty_trace(tmp_path, capsys):
    path = str(tmp_path / "empty.json")
    json.dump({"traceEvents": []}, open(path, "w"))
    from repro.obs.__main__ import main
    assert main(["report", path]) == 1
