"""TaskComm.reshard -- the one-call user face of the M->N subsystem."""

import threading

import numpy as np
import pytest

from repro.core import Wilkins, h5
from repro.core.comm import TaskComm, world
from repro.core.datamodel import BlockOwnership, File
from repro.core.redistribute import (RedistSpec, even_blocks, plan_cache,
                                     redistribute_numpy, reset_plan_cache)
from test_redistribute import ragged_blocks


def _spec(axis=0, nslots=1, slot=0, nranks=2):
    return RedistSpec(axis=axis, nslots=nslots, slot=slot, nranks=nranks)


def test_reshard_matches_redistribute_numpy_1d():
    g = np.arange(97.0)
    spec = _spec(nranks=3)
    got = TaskComm().reshard(g, spec, ranks="all")
    want = redistribute_numpy(g, [((0,), g.shape)], spec.dst_boxes(g.shape)[0])
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, a)


def test_reshard_matches_redistribute_numpy_2d_both_axes():
    g = np.arange(23 * 17, dtype=np.float32).reshape(23, 17)
    for axis in (0, 1):
        spec = _spec(axis=axis, nslots=2, slot=1, nranks=2)
        dst, _ = spec.dst_boxes(g.shape)
        want = redistribute_numpy(g, [((0, 0), g.shape)], dst)
        got = TaskComm().reshard(g, spec, ranks="all")
        for w, a in zip(want, got):
            np.testing.assert_array_equal(w, a)
        mine = TaskComm().reshard(g, spec)  # ranks="mine" default
        for r, a in zip(spec.my_ranks(), mine):
            np.testing.assert_array_equal(want[r], a)


def test_reshard_ragged_src_decomposition():
    rng = np.random.default_rng(5)
    g = rng.normal(size=(41, 6))
    src = ragged_blocks(41, 4, rng, shape=g.shape)
    spec = _spec(nslots=3, slot=2, nranks=2)
    dst, _ = spec.dst_boxes(g.shape)
    want = redistribute_numpy(g, src, dst)
    got = TaskComm().reshard(g, spec, src=src, ranks="all")
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, a)


def test_reshard_dataset_ownership_is_src_decomposition():
    f = File("o.h5")
    g = np.arange(64.0)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks(g.shape, 4)):
        own.add(r, s, sh)
    ds = f.create_dataset("/g", data=g)
    ds.ownership = own
    spec = _spec(nranks=2)
    reset_plan_cache()
    got = TaskComm().reshard(ds, spec, ranks="all")
    want = redistribute_numpy(g, [own.blocks[r] for r in range(4)],
                              spec.dst_boxes(g.shape)[0])
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, a)
    # the plan key is the dataset's REAL ownership, not one global block
    assert plan_cache().snapshot()["misses"] == 1


def test_reshard_4to2_axis1_device_pack_path():
    """Acceptance: 4->2 axis-1 decomposition, bit-exact through the pack
    kernel (prefer="pack" forbids any numpy fallback)."""
    import jax
    import jax.numpy as jnp

    g = np.arange(16 * 52, dtype=np.float32).reshape(16, 52)
    src = even_blocks(g.shape, 4, axis=1)
    spec = RedistSpec(axis=1, nslots=2, slot=0, nranks=1)
    dst, _ = spec.dst_boxes(g.shape)
    want = redistribute_numpy(g, src, dst)
    got = TaskComm().reshard(jnp.asarray(g), spec, src=src, ranks="all",
                             prefer="pack", tile_rows=4)
    assert all(isinstance(b, jax.Array) for b in got)
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))
    plan = plan_cache().get(src, dst, g.shape, g.dtype)
    assert plan.pack_mode == "cols"


def test_reshard_device_rows_pack_path():
    import jax.numpy as jnp

    g = np.arange(37 * 8, dtype=np.float32).reshape(37, 8)
    spec = _spec(nslots=2, slot=1, nranks=2)
    dst, _ = spec.dst_boxes(g.shape)
    want = redistribute_numpy(g, [((0, 0), g.shape)], dst)
    got = TaskComm().reshard(jnp.asarray(g), spec, prefer="pack")
    for r, a in zip(spec.my_ranks(), got):
        np.testing.assert_array_equal(want[r], np.asarray(a))


def test_reshard_prefer_pack_raises_when_unlowerable():
    spec = _spec(nranks=2)
    with pytest.raises(ValueError, match="pack-kernel path unavailable"):
        TaskComm().reshard(np.zeros(8), spec, prefer="pack")  # numpy + 1-D


def test_reshard_spec_resolution_errors():
    c = TaskComm()
    with pytest.raises(ValueError, match="no RedistSpec wired"):
        c.reshard(np.zeros(8))
    c2 = TaskComm(redist_specs={"a.h5": _spec(nranks=1),
                                "b.h5": _spec(nranks=2)})
    with pytest.raises(ValueError, match="distinct RedistSpecs"):
        c2.reshard(np.zeros(8))
    with pytest.raises(ValueError, match="no RedistSpec for port"):
        c2.reshard(np.zeros(8), port="c.h5")
    # port= selects; sole-spec comms resolve implicitly
    assert len(c2.reshard(np.zeros(8), port="b.h5", ranks="all")) == 2
    c3 = TaskComm(redist_specs={"a.h5": _spec(nranks=4)})
    assert len(c3.reshard(np.zeros(8), ranks="all")) == 4


def test_reshard_rank_out_of_range():
    with pytest.raises(ValueError, match="out of range"):
        TaskComm().reshard(np.zeros(8), _spec(nranks=2), ranks=[5])


def test_reshard_in_workflow_consumer_slab():
    """End-to-end: consumers receive their slab over a redistributing
    channel and reshard it onto their logical ranks with one call."""
    yaml = """
tasks:
  - func: producer
    taskCount: 4
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    taskCount: 2
    nprocs: 2
    inports:
      - filename: o.h5
        redistribute: 1
        dsets: [{name: /g, memory: 1}]
"""
    n = 64
    g = np.arange(n, dtype=np.float64)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks(g.shape, 4)):
        own.add(r, s, sh)
    got = {}
    lock = threading.Lock()

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=g, ownership=own)

    def consumer(comm):
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            blocks = comm.reshard(f["/g"])  # spec resolved from the driver
            with lock:
                got[comm.instance] = [np.asarray(b) for b in blocks]

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    assert sorted(got) == [0, 1]
    for inst in (0, 1):
        spec = RedistSpec(axis=0, nslots=2, slot=inst, nranks=2)
        dst, _ = spec.dst_boxes(g.shape)
        assert len(got[inst]) == 2
        for r, b in zip(spec.my_ranks(), got[inst]):
            starts, shape = dst[r]
            np.testing.assert_array_equal(
                b, g[starts[0]:starts[0] + shape[0]])


def test_reshard_slab_rejects_foreign_ranks():
    """A received slab can only be resharded onto the ranks it covers."""
    f = File("o.h5")
    ds = f.create_dataset("/g", data=np.arange(32.0))
    ds.attrs["redist_global_shape"] = [64]
    ds.attrs["redist_box_starts"] = [32]
    spec = RedistSpec(axis=0, nslots=2, slot=1, nranks=2)
    # my ranks (2, 3) live inside the slab: fine
    blocks = TaskComm().reshard(ds, spec)
    np.testing.assert_array_equal(blocks[0], np.arange(0.0, 16.0))
    np.testing.assert_array_equal(blocks[1], np.arange(16.0, 32.0))
    # rank 0 belongs to the sibling instance's slab
    with pytest.raises(ValueError, match="not covered by the received slab"):
        TaskComm().reshard(ds, spec, ranks=[0])


# ---------------------------------------------------------------------------
# YAML producer ownership (outports: {ownership: {axis: A}})
# ---------------------------------------------------------------------------
def _graph(yaml):
    from repro.core import WorkflowGraph
    return WorkflowGraph.from_yaml(yaml)


def test_yaml_ownership_parses():
    g = _graph("""
tasks:
  - func: p
    nprocs: 4
    outports:
      - filename: o.h5
        ownership: {axis: 1}
        dsets: [{name: /g, memory: 1}]
""")
    port = g.tasks["p"].outports[0]
    assert port.ownership and port.own_axis == 1 and port.own_nranks is None
    g2 = _graph("""
tasks:
  - func: p
    nprocs: 4
    outports:
      - filename: o.h5
        ownership: {nranks: 4}
""")
    assert g2.tasks["p"].outports[0].own_nranks == 4


@pytest.mark.parametrize("ownership, err", [
    ("{axis: -1}", "axis must be >= 0"),
    ("{nranks: 0}", "nranks must be >= 1"),
    ("{axis: 0, blocks: 3}", "unknown ownership keys"),
])
def test_yaml_ownership_bad_values(ownership, err):
    with pytest.raises(ValueError, match=err):
        _graph(f"""
tasks:
  - func: p
    outports:
      - filename: o.h5
        ownership: {ownership}
""")


def test_yaml_ownership_mismatched_nranks():
    with pytest.raises(ValueError, match="matches neither nprocs=4 nor nwriters=4"):
        _graph("""
tasks:
  - func: p
    nprocs: 4
    outports:
      - filename: o.h5
        ownership: {nranks: 3}
""")
    # nwriters is an accepted block count (subset writers)
    g = _graph("""
tasks:
  - func: p
    nprocs: 4
    nwriters: 2
    outports:
      - filename: o.h5
        ownership: {nranks: 2}
""")
    assert g.tasks["p"].outports[0].own_nranks == 2


def test_yaml_ownership_rejected_on_inports():
    with pytest.raises(ValueError, match="ownership is an outport declaration"):
        _graph("""
tasks:
  - func: c
    inports:
      - filename: o.h5
        ownership: 1
""")


def test_vol_stamps_ownership_at_close():
    from repro.core.vol import VOL

    vol = VOL("p", nprocs=4)
    vol.set_ownership("o.h5", axis=0, nranks=4)
    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(16.0))
    pre = BlockOwnership()
    pre.add(0, (0,), (16,))
    f.create_dataset("/h", data=np.arange(16.0)).ownership = pre
    f.create_dataset("/s", data=np.float64(3.0), shape=(), dtype=np.float64)
    vol.on_file_close(f)
    assert f["/g"].ownership.blocks == {
        0: ((0,), (4,)), 1: ((4,), (4,)), 2: ((8,), (4,)), 3: ((12,), (4,))}
    assert f["/h"].ownership is pre          # explicit ownership wins
    assert f["/s"].ownership is None         # scalars skipped


def test_vol_ownership_axis_out_of_range_is_clear():
    from repro.core.vol import VOL

    vol = VOL("p", nprocs=2)
    vol.set_ownership("o.h5", axis=2, nranks=2)
    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(8.0))
    with pytest.raises(ValueError, match="axis 2 out of range"):
        vol.on_file_close(f)


def test_yaml_ownership_flows_into_plan_src():
    """Producer declares ownership in YAML only; the redistribution plan
    sees the 4-block src decomposition, not one global block."""
    yaml = """
tasks:
  - func: producer
    nprocs: 4
    outports:
      - filename: o.h5
        ownership: 1
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    taskCount: 2
    nprocs: 1
    inports:
      - filename: o.h5
        redistribute: 1
        dsets: [{name: /g, memory: 1}]
"""
    n = 64
    got = {}
    lock = threading.Lock()

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(n, dtype=np.float64))

    def consumer(comm):
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            with lock:
                got[comm.instance] = np.asarray(f["/g"][:])

    reset_plan_cache()
    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    np.testing.assert_array_equal(got[0], np.arange(32.0))
    np.testing.assert_array_equal(got[1], np.arange(32.0, 64.0))
    src4 = even_blocks((n,), 4)
    dst, _ = RedistSpec(axis=0, nslots=2, slot=0, nranks=1).dst_boxes((n,))
    plan = plan_cache().get(src4, dst, (n,), np.float64)
    assert len(plan.src) == 4   # already compiled during the run (cache hit)
    assert plan_cache().snapshot()["misses"] == 1


def test_yaml_prefetch_rejected_on_outports():
    with pytest.raises(ValueError, match="prefetch is an inport declaration"):
        _graph("""
tasks:
  - func: p
    outports:
      - filename: o.h5
        prefetch: 1
""")


def test_reshard_producer_wired_spec_requires_explicit_ranks():
    """A producer feeding a redistributing port has no 'mine': the default
    reshard errors clearly; ranks='all' sees the full consumer layout."""
    yaml = """
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    taskCount: 2
    nprocs: 2
    inports:
      - filename: o.h5
        redistribute: 1
        dsets: [{name: /g, memory: 1}]
"""
    n = 32
    g = np.arange(n, dtype=np.float64)
    results = {}

    def producer(comm):
        with pytest.raises(ValueError, match="has no 'mine'"):
            comm.reshard(g)
        results["all"] = comm.reshard(g, ranks="all")
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=g)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    assert len(results["all"]) == 4          # 2 slots x 2 ranks
    np.testing.assert_array_equal(results["all"][0], g[:8])
    np.testing.assert_array_equal(results["all"][3], g[24:])
