"""N-D pack lowering, device-slab dispatch, per-edge prefetch depth, and the
PR-4 bugfix regressions (io_freq validation, prefetch executor lifecycle,
restricted-world mesh errors)."""

import threading
import time

import numpy as np
import pytest

from repro.core import Wilkins, h5
from repro.core.channel import (Channel, DEFAULT_PREFETCH_DEPTH, FlowControl,
                                PrefetchPool, configure_prefetch_pool,
                                shutdown_prefetch_pool)
from repro.core import channel as channel_mod
from repro.core.comm import TaskComm
from repro.core.datamodel import (BlockOwnership, File, is_device_array,
                                  reset_transport_stats, transport_stats)
from repro.core.graph import WorkflowGraph
from repro.core.redistribute import (CompiledPlan, RedistSpec, even_blocks,
                                     execute_pack_jax, execute_pack_jax_all,
                                     plan_cache, redistribute_numpy,
                                     reset_plan_cache)


# ---------------------------------------------------------------------------
# N-D pack lowering (flatten transform)
# ---------------------------------------------------------------------------
def _ref(g, src, dst):
    return redistribute_numpy(g, src, dst)


@pytest.mark.parametrize("shape, axis, m_src, m_dst, tile", [
    ((37, 5, 6), 0, 4, 3, 4),    # 3-D rows lowering (ragged axis extent)
    ((6, 40, 3), 1, 3, 2, 4),    # 3-D middle axis -> flattened cols, scale>1
    ((4, 6, 23), 2, 4, 5, 4),    # 3-D last axis
    ((3, 4, 5, 23), 3, 4, 5, 4),  # 4-D last axis
    ((23, 3, 4, 5), 0, 5, 2, 8),  # 4-D rows
    ((3, 17, 4, 5), 1, 2, 3, 4),  # 4-D middle axis
])
def test_nd_pack_matches_numpy_reference(shape, axis, m_src, m_dst, tile):
    import jax.numpy as jnp

    rng = np.random.default_rng(hash(shape) % 2**31)
    g = rng.normal(size=shape).astype(np.float32)
    src = even_blocks(shape, m_src, axis=axis)
    dst = even_blocks(shape, m_dst, axis=axis)
    plan = CompiledPlan(src, dst, shape, g.dtype)
    assert plan.pack_mode == ("rows" if axis == 0 else "cols")
    assert plan.pack_axis == axis
    want = _ref(g, src, dst)
    got = execute_pack_jax_all(plan, jnp.asarray(g), tile_rows=tile)
    assert len(got) == m_dst
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))
    # single-rank entry point agrees
    one = execute_pack_jax(plan, m_dst - 1, jnp.asarray(g), tile_rows=tile)
    np.testing.assert_array_equal(want[-1], np.asarray(one))


def test_nd_cross_axis_exchange_lowers_via_dst_axis():
    """src along axis 0, dst along axis 2: per-dst runs coalesce to
    full-extent axis-2 slabs, so the exchange stays on the kernel path."""
    import jax.numpy as jnp

    g = np.arange(8 * 3 * 24, dtype=np.float32).reshape(8, 3, 24)
    plan = CompiledPlan(even_blocks(g.shape, 4, axis=0),
                        even_blocks(g.shape, 3, axis=2), g.shape, g.dtype)
    assert plan.pack_mode == "cols" and plan.pack_axis == 2
    want = plan.execute_global(g)
    got = execute_pack_jax_all(plan, jnp.asarray(g), tile_rows=4)
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))


def test_nd_genuinely_cross_axis_falls_back_to_numpy():
    """A 3-D quadrant tiling decomposes TWO axes: no single-axis flatten
    exists, pack_mode is None, and reshard takes the scatter executors."""
    import jax.numpy as jnp

    shape = (8, 8, 3)
    quads = [((0, 0, 0), (4, 4, 3)), ((0, 4, 0), (4, 4, 3)),
             ((4, 0, 0), (4, 4, 3)), ((4, 4, 0), (4, 4, 3))]
    plan = CompiledPlan([((0, 0, 0), shape)], quads, shape, np.float32)
    assert plan.pack_mode is None and plan.pack_axis is None
    with pytest.raises(ValueError, match="not pack-kernel lowerable"):
        execute_pack_jax(plan, 0, jnp.zeros(shape, jnp.float32))
    g = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    want = redistribute_numpy(g, [((0, 0, 0), shape)], quads)
    got = plan.execute_global(g)
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, a)


def test_reshard_rank3_device_array_takes_pack_path():
    """Acceptance: rank-3 reshard of a device array runs the pack kernels
    (prefer="pack" forbids numpy fallback) and is byte-identical to
    redistribute_numpy."""
    import jax
    import jax.numpy as jnp

    g = np.arange(24 * 5 * 6, dtype=np.float32).reshape(24, 5, 6)
    spec = RedistSpec(axis=0, nslots=2, slot=0, nranks=2)
    dst, _ = spec.dst_boxes(g.shape)
    want = redistribute_numpy(g, [((0, 0, 0), g.shape)], dst)
    reset_plan_cache()
    reset_transport_stats()
    got = TaskComm().reshard(jnp.asarray(g), spec, ranks="all",
                             prefer="pack", tile_rows=4)
    assert all(isinstance(b, jax.Array) for b in got)
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))
    plan = plan_cache().get([((0, 0, 0), g.shape)], dst, g.shape, g.dtype)
    assert plan.pack_mode == "rows"   # no numpy fallback was possible
    s = transport_stats().snapshot()
    assert s["reshard_pack"] == 1 and s["reshard_numpy"] == 0


def test_reshard_rank3_middle_axis_device_array():
    import jax.numpy as jnp

    g = np.arange(6 * 40 * 3, dtype=np.float32).reshape(6, 40, 3)
    spec = RedistSpec(axis=1, nslots=2, slot=1, nranks=2)
    dst, _ = spec.dst_boxes(g.shape)
    want = redistribute_numpy(g, [((0, 0, 0), g.shape)], dst)
    got = TaskComm().reshard(jnp.asarray(g), spec, prefer="pack", tile_rows=4)
    for r, a in zip(spec.my_ranks(), got):
        np.testing.assert_array_equal(want[r], np.asarray(a))


# ---------------------------------------------------------------------------
# device-slab pack-path dispatch
# ---------------------------------------------------------------------------
def _slab_dataset(g, spec, slot, data_transform=lambda x: x):
    """Build the Dataset a redistributing channel would ship to ``slot``."""
    dst, slots = spec.dst_boxes(g.shape)
    starts, shape = slots[slot]
    slc = tuple(slice(s, s + n) for s, n in zip(starts, shape))
    f = File("o.h5")
    ds = f.create_dataset("/g", data=data_transform(g[slc]), copy=False)
    ds.attrs["redist_global_shape"] = list(g.shape)
    ds.attrs["redist_box_starts"] = list(starts)
    return ds, dst


def test_device_slab_dataset_dispatches_to_pack_kernels():
    """A received slab backed by a device array reshards on the kernel path:
    the dispatch probes the READ BUFFER (a Dataset is not a jax.Array), and
    the gathers run in slab-local source coordinates."""
    import jax
    import jax.numpy as jnp

    g = np.arange(32 * 5 * 2, dtype=np.float32).reshape(32, 5, 2)
    spec = RedistSpec(axis=0, nslots=2, slot=1, nranks=2)
    ds, dst = _slab_dataset(g, spec, 1, jnp.asarray)
    assert is_device_array(ds.read_direct())
    want = redistribute_numpy(g, [((0, 0, 0), g.shape)], dst)
    blocks = TaskComm().reshard(ds, spec, prefer="pack", tile_rows=4)
    assert all(isinstance(b, jax.Array) for b in blocks)
    for r, b in zip(spec.my_ranks(), blocks):
        np.testing.assert_array_equal(want[r], np.asarray(b))
    # foreign ranks live outside the received slab, kernel path or not
    with pytest.raises(ValueError, match="not covered by the received slab"):
        TaskComm().reshard(ds, spec, ranks=[0], prefer="pack")


def test_device_slab_2d_axis1_pack_dispatch():
    import jax.numpy as jnp

    g = np.arange(8 * 48, dtype=np.float32).reshape(8, 48)
    spec = RedistSpec(axis=1, nslots=2, slot=0, nranks=2)
    ds, dst = _slab_dataset(g, spec, 0, jnp.asarray)
    want = redistribute_numpy(g, [((0, 0), g.shape)], dst)
    blocks = TaskComm().reshard(ds, spec, prefer="pack", tile_rows=4)
    for r, b in zip(spec.my_ranks(), blocks):
        np.testing.assert_array_equal(want[r], np.asarray(b))


def test_slab_covering_only_run_head_raises_not_corrupts():
    """A slab that covers the START of a dst rank's run but not its tail
    must raise -- a clamped out-of-bounds tile DMA would silently return
    duplicated/zero rows instead."""
    import jax.numpy as jnp

    shape = (100, 8)
    dst = [((40, 0), (30, 8))]       # the rank's run needs rows 40-69
    plan = CompiledPlan([((0, 0), shape)], dst, shape, np.float32)
    slab_box = ((40, 0), (10, 8))    # but the slab holds rows 40-49 only
    slab = jnp.zeros((10, 8), jnp.float32)
    with pytest.raises(ValueError, match="does not cover this rank"):
        execute_pack_jax(plan, 0, slab, tile_rows=8, slab_box=slab_box)


def test_host_slab_dataset_still_uses_numpy_scatter():
    g = np.arange(32 * 3, dtype=np.float64).reshape(32, 3)
    spec = RedistSpec(axis=0, nslots=2, slot=0, nranks=2)
    ds, dst = _slab_dataset(g, spec, 0, np.array)
    want = redistribute_numpy(g, [((0, 0), g.shape)], dst)
    blocks = TaskComm().reshard(ds, spec)
    assert all(isinstance(b, np.ndarray) for b in blocks)
    for r, b in zip(spec.my_ranks(), blocks):
        np.testing.assert_array_equal(want[r], b)


def test_device_dataset_cow_write_materializes_host_copy():
    """Device buffers are immutable: a write through the Dataset CoW layer
    lands in a private host copy, never corrupting the device payload."""
    import jax.numpy as jnp

    f = File("o.h5")
    src = jnp.arange(8.0)
    ds = f.create_dataset("/g", data=src, copy=False)
    assert is_device_array(ds.read_direct())
    ds[0] = -1.0
    got = ds.read_direct()
    assert isinstance(got, np.ndarray) and got[0] == -1.0
    assert float(src[0]) == 0.0


# ---------------------------------------------------------------------------
# satellite: io_freq validation at graph parse time
# ---------------------------------------------------------------------------
def test_io_freq_typo_rejected_at_parse_naming_task_and_port():
    yaml = """
tasks:
  - func: sim
    outports:
      - filename: o.h5
  - func: ana
    inports:
      - filename: o.h5
        io_freq: -2
"""
    with pytest.raises(ValueError, match=r"task 'ana' port 'o.h5'.*io_freq -2"):
        WorkflowGraph.from_yaml(yaml)


def test_io_freq_valid_values_still_parse():
    for freq in (0, 1, 2, 7, -1):
        g = WorkflowGraph.from_yaml(f"""
tasks:
  - func: ana
    inports:
      - filename: o.h5
        io_freq: {freq}
""")
        assert g.tasks["ana"].inports[0].io_freq == freq


def test_flow_control_decode_still_guards():
    with pytest.raises(ValueError, match="invalid io_freq -2"):
        FlowControl.from_io_freq(-2)


# ---------------------------------------------------------------------------
# satellite: prefetch executor lifecycle
# ---------------------------------------------------------------------------
def _mxn_yaml(extra=""):
    return f"""
tasks:
  - func: producer
    taskCount: 2
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    taskCount: 2
    nprocs: 1
    inports:
      - filename: o.h5
        redistribute: 1
        {extra}
        dsets: [{{name: /g, memory: 1}}]
"""


def _owned(n, m):
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), m)):
        own.add(r, s, sh)
    return own


def _run_pool_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("wilkins-prefetch-run")]


def _wait_no_run_pool_threads(timeout=5.0):
    deadline = time.monotonic() + timeout
    while _run_pool_threads() and time.monotonic() < deadline:
        time.sleep(0.01)
    return not _run_pool_threads()


def test_prefetch_pool_torn_down_after_successful_run():
    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(64.0), ownership=_owned(64, 2))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break

    shutdown_prefetch_pool()
    w = Wilkins(_mxn_yaml(), {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    # the run-scoped pool was shut down (workers drained) and the channels
    # detached; the run never touched the module-default pool
    assert all(c._prefetch_pool is None for c in w.channels)
    assert _wait_no_run_pool_threads()
    assert channel_mod._PREFETCH_POOL is None


def test_prefetch_pool_torn_down_on_error_path():
    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(64.0), ownership=_owned(64, 2))

    def consumer():
        raise RuntimeError("consumer boom")

    shutdown_prefetch_pool()
    w = Wilkins(_mxn_yaml(), {"producer": producer, "consumer": consumer})
    with pytest.raises(RuntimeError, match="consumer boom"):
        w.run(timeout=60)
    assert all(c._prefetch_pool is None for c in w.channels)
    assert _wait_no_run_pool_threads()
    assert channel_mod._PREFETCH_POOL is None


def test_concurrent_runs_use_independent_pools():
    """Two workflows running in one process must not cancel each other's
    preps: each run owns its pool, injected per channel."""
    barrier = threading.Barrier(2, timeout=30)
    pools = {}
    lock = threading.Lock()

    def make_funcs(tag):
        def producer():
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(64.0),
                                 ownership=_owned(64, 2))

        def consumer():
            while True:
                f = h5.File("o.h5", "r")
                if f is None:
                    break

        return {"producer": producer, "consumer": consumer}

    def run_one(tag):
        w = Wilkins(_mxn_yaml(), make_funcs(tag))
        orig_run = w.run

        barrier.wait()
        rep = orig_run(timeout=60)
        with lock:
            pools[tag] = rep
        return rep

    ts = [threading.Thread(target=run_one, args=(i,), daemon=True)
          for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(60)
        assert not t.is_alive()
    # both runs completed and served every payload despite overlapping
    # (2x2 round-robin pairing = 2 channels, one serve each)
    assert len(pools) == 2
    for rep in pools.values():
        assert rep.total_served == 2
    assert _wait_no_run_pool_threads()


def test_prefetch_pool_workers_are_daemon_and_drain_on_shutdown():
    pool = PrefetchPool(max_workers=2, thread_name_prefix="t-pool")
    assert all(t.daemon for t in pool._threads)
    assert pool.submit(lambda: 41 + 1).result(timeout=5) == 42
    pool.shutdown()
    deadline = time.monotonic() + 5
    while pool.alive_workers() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert pool.alive_workers() == 0
    with pytest.raises(RuntimeError, match="shut down"):
        pool.submit(lambda: None)


def test_prefetch_pool_shutdown_cancels_queued_preps():
    started = threading.Event()
    release = threading.Event()

    def blocker():
        started.set()
        release.wait(10)
        return "done"

    pool = PrefetchPool(max_workers=1)
    f1 = pool.submit(blocker)
    assert started.wait(5)
    f2 = pool.submit(lambda: "never runs")   # queued behind the blocker
    pool.shutdown()
    assert f2.cancelled()
    release.set()
    assert f1.result(timeout=5) == "done"    # running preps finish normally


def test_configure_prefetch_pool_replaces_and_shuts_old():
    old = configure_prefetch_pool(1)
    new = configure_prefetch_pool(2)
    assert new is not old
    with pytest.raises(RuntimeError):
        old.submit(lambda: None)
    shutdown_prefetch_pool()
    assert channel_mod._PREFETCH_POOL is None


# ---------------------------------------------------------------------------
# per-edge prefetch depth
# ---------------------------------------------------------------------------
def test_prefetch_yaml_depth_parses_and_reaches_channel():
    w = Wilkins(_mxn_yaml(extra="prefetch: 3"),
                {"producer": lambda: None, "consumer": lambda: None})
    assert all(c.prefetch == 3 for c in w.channels)
    w2 = Wilkins(_mxn_yaml(), {"producer": lambda: None,
                               "consumer": lambda: None})
    assert all(c.prefetch == DEFAULT_PREFETCH_DEPTH for c in w2.channels)
    with pytest.raises(ValueError, match="prefetch depth must be >= 0"):
        WorkflowGraph.from_yaml(_mxn_yaml(extra="prefetch: -1"))


@pytest.mark.slow
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_prefetch_depth_bounds_inflight_preps_per_edge(depth):
    """Under contention (slow preps, deep queue) at most ``depth`` payload
    preparations for one edge run concurrently."""
    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(16.0))
    ch = Channel("c", ("p", 0), ("c", 0), "o.h5", ["/g"], queue_depth=8,
                 redistribute=RedistSpec(axis=0, nslots=2, slot=0, nranks=1),
                 prefetch=depth)
    configure_prefetch_pool(8)   # pool never the bottleneck
    lock = threading.Lock()
    state = {"cur": 0, "max": 0}
    orig = ch._prepare

    def slow_prepare(*a, **kw):
        with lock:
            state["cur"] += 1
            state["max"] = max(state["max"], state["cur"])
        try:
            time.sleep(0.05)
            return orig(*a, **kw)
        finally:
            with lock:
                state["cur"] -= 1

    ch._prepare = slow_prepare
    try:
        consumed = []

        def consume():
            while True:
                got = ch.get(timeout=20)
                if got is None:
                    return
                consumed.append(got)

        t = threading.Thread(target=consume, daemon=True)
        t.start()
        for _ in range(8):
            assert ch.offer(f)
        ch.finish()
        t.join(30)
        assert not t.is_alive()
        assert len(consumed) == 8
        assert state["max"] <= depth
    finally:
        shutdown_prefetch_pool()


@pytest.mark.slow
def test_prefetch_depth_is_per_edge_not_global():
    """Two edges with depth 1 each may overlap with each other (2 preps in
    flight globally) but never within one edge."""
    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(16.0))
    spec = RedistSpec(axis=0, nslots=2, slot=0, nranks=1)
    chans = [Channel(f"c{i}", ("p", 0), ("c", i), "o.h5", ["/g"],
                     queue_depth=4, redistribute=spec, prefetch=1)
             for i in range(2)]
    configure_prefetch_pool(4)
    lock = threading.Lock()
    per_edge = {c.name: {"cur": 0, "max": 0} for c in chans}
    global_state = {"cur": 0, "max": 0}

    def wrap(ch):
        orig = ch._prepare

        def slow(*a, **kw):
            with lock:
                per_edge[ch.name]["cur"] += 1
                per_edge[ch.name]["max"] = max(per_edge[ch.name]["max"],
                                               per_edge[ch.name]["cur"])
                global_state["cur"] += 1
                global_state["max"] = max(global_state["max"],
                                          global_state["cur"])
            try:
                time.sleep(0.05)
                return orig(*a, **kw)
            finally:
                with lock:
                    per_edge[ch.name]["cur"] -= 1
                    global_state["cur"] -= 1

        ch._prepare = slow

    for c in chans:
        wrap(c)
    try:
        threads = []

        def drain(ch):
            while ch.get(timeout=20) is not None:
                pass

        for c in chans:
            t = threading.Thread(target=drain, args=(c,), daemon=True)
            t.start()
            threads.append(t)

        def produce(ch):
            for _ in range(4):
                ch.offer(f)
            ch.finish()

        producers = [threading.Thread(target=produce, args=(c,), daemon=True)
                     for c in chans]
        for p in producers:
            p.start()
        for th in producers + threads:
            th.join(30)
            assert not th.is_alive()
        for c in chans:
            assert per_edge[c.name]["max"] <= 1
    finally:
        shutdown_prefetch_pool()


# ---------------------------------------------------------------------------
# satellite: restricted-world mesh validation
# ---------------------------------------------------------------------------
def test_mesh_overcommit_raises_clear_error():
    comm = TaskComm(task="sim", devices=[object(), object()])
    with pytest.raises(ValueError, match=r"task 'sim'.*mesh shape \(4,\) "
                                         r"needs 4 devices.*holds only 2"):
        comm.mesh(shape=(4,))
    with pytest.raises(ValueError, match="restricted device group"):
        comm.mesh(shape=(2, 2))


def test_mesh_within_budget_still_builds():
    import jax

    comm = TaskComm(task="sim", devices=list(jax.devices())[:1])
    m = comm.mesh(shape=(1,))
    assert m.devices.shape == (1,)


# ---------------------------------------------------------------------------
# WorkflowReport.summary counters (acceptance)
# ---------------------------------------------------------------------------
def test_summary_prints_prefetch_and_plan_cache_counters():
    n, steps = 128, 3

    def producer():
        own = _owned(n, 2)
        for _ in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(n, dtype=np.float64),
                                 ownership=own)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break

    reset_plan_cache()
    reset_transport_stats()
    w = Wilkins(_mxn_yaml(), {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    s = rep.summary()
    assert "prefetch: hits=" in s and "blocked_s=" in s
    assert "plan_cache: size=" in s and "hit_rate=" in s
    assert "redist: planned=" in s
    assert rep.transport["prefetch_hits"] + rep.transport["prefetch_misses"] > 0
    assert rep.plan_cache["misses"] >= 1


def test_summary_counters_present_on_error_report():
    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(16.0), ownership=_owned(16, 2))

    def consumer():
        raise RuntimeError("boom")

    w = Wilkins(_mxn_yaml(), {"producer": producer, "consumer": consumer})
    with pytest.raises(RuntimeError) as ei:
        w.run(timeout=60)
    rep = ei.value.report
    assert "plan_cache:" in rep.summary()
