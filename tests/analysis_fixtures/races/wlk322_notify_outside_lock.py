# expect: code=WLK322
"""Seeded lost wakeup: the producer publishes the flag and notifies
WITHOUT taking the condition's lock, so the notify can land in the gap
between the consumer's predicate check and its ``wait`` -- the wakeup is
lost and the consumer parks forever.

Real ``threading.Condition`` turns an un-held ``notify`` into a hard
``RuntimeError``; the explorer's model CV deliberately permits it (lossy
wake of current waiters only) exactly so this hazard is *explorable*:
the bad interleaving needs one preemption and reports WLK322."""

from repro.analysis import lockcheck
from repro.analysis.lockcheck import make_condition

CODE = "WLK322"
BUDGET = 32


def build():
    cv = make_condition("leaf:flag")
    state = {"flag": False}

    def consumer():
        with cv:
            while not state["flag"]:
                # the check-to-wait gap the missing lock leaves open
                lockcheck.sched_point("predicate-to-wait gap",
                                      key=("flag", 0))
                cv.wait()

    def producer():
        # BUG: flag store + notify outside the CV's lock
        state["flag"] = True
        cv.notify()

    return [("consumer", consumer), ("producer", producer)]
