# expect: code=WLK321
"""Seeded deadlock: the classic AB-BA lock-order inversion between two
leaf locks.  The runtime lock-order recorder (WLK310) can only flag this
if a run happens to interleave badly; the explorer proves it by
*constructing* the interleaving and reports WLK321 with a replayable
schedule ID."""

from repro.analysis.lockcheck import make_lock

CODE = "WLK321"
BUDGET = 32


def build():
    a = make_lock("leaf:a")
    b = make_lock("leaf:b")

    def t_ab():
        with a:
            with b:
                pass

    def t_ba():
        with b:
            with a:
                pass

    return [("t_ab", t_ab), ("t_ba", t_ba)]
