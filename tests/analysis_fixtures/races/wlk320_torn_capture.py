# expect: code=WLK320
"""Seeded race (PR 3's torn-capture bug, re-introduced): a reader
captures the shared payload buffer BEFORE the hand-off protocol orders
it, then reads through the stale capture while the writer mutates the
same buffer in place (the pre-CoW behavior: no copy before write).

The fixed protocol copies on first write under the share lock, so reader
and writer never touch one buffer unordered; this fixture drops both the
copy and the lock, and the shadow-state checker must report WLK320 with
the reader's and the writer's stacks."""

from repro.analysis.explore.instrument import TrackedCell

CODE = "WLK320"
BUDGET = 16


def build():
    share = {"buf": TrackedCell("payload", 0)}
    seen = []

    def writer():
        # BUG: mutates the shared buffer in place instead of copying
        share["buf"].write(7)

    def reader():
        buf = share["buf"]     # captures the buffer, not a snapshot
        seen.append(buf.read())

    return [("writer", writer), ("reader", reader)]
