# expect: code=WLK323
"""Seeded protocol bug: a crash-replay dedup watermark with an
off-by-one (``seq < delivered`` where the channel uses ``seq <=``).

A producer crash rewinds the serve counter and re-offers everything
since the last ack; whether the consumer already drained some of those
steps is schedule-dependent.  With the buggy comparison the replayed
copy of the LAST drained step passes the dedup check and is delivered
twice -- but only on schedules where the consumer drained at least one
item before the crash, which is exactly what the explorer enumerates.
The duplicated delivery trips the consumer's exactly-once assertion and
reports WLK323 with a replayable schedule ID."""

from repro.analysis.lockcheck import make_condition

CODE = "WLK323"
BUDGET = 128
_SKIP = object()


class _MiniChannel:
    """A depth-unbounded mini-channel with the PR 6 replay protocol and
    the dedup watermark re-broken."""

    def __init__(self):
        self.cv = make_condition("leaf:mini")
        self.queue = []
        self.delivered = 0
        self.done = False

    def offer(self, seq):
        with self.cv:
            self.queue.append(seq)
            self.cv.notify()

    def crash(self):
        # quarantine: the in-flight queue is dropped; the restarted
        # incarnation will re-offer from the last ack (seq 1)
        with self.cv:
            self.queue.clear()

    def finish(self):
        with self.cv:
            self.done = True
            self.cv.notify_all()

    def get(self):
        with self.cv:
            while not self.queue and not self.done:
                self.cv.wait()
            if not self.queue:
                return None
            seq = self.queue.pop(0)
            if seq < self.delivered:   # BUG: replayed seq==delivered slips through (should be <=)
                return _SKIP
            self.delivered = seq
            return seq


def build():
    ch = _MiniChannel()
    got = []

    def producer():
        ch.offer(1)
        ch.offer(2)
        ch.crash()
        for seq in (1, 2, 3):
            ch.offer(seq)
        ch.finish()

    def consumer():
        while True:
            seq = ch.get()
            if seq is None:
                break
            if seq is _SKIP:
                continue
            got.append(seq)
        assert got == [1, 2, 3], \
            f"replay broke exactly-once delivery: {got}"

    return [("producer", producer), ("consumer", consumer)]
