# expect: code=WLK320
"""Seeded race (PR 8's torn-stats bug, re-introduced): two transport
threads bump the same stats counter with an unlocked read-modify-write.

The explorer must flag the HB-unordered accesses as WLK320 -- the two
``add`` calls carry no lock and no happens-before edge, so even the
sequential schedules are racy (FastTrack semantics: unordered, not
merely simultaneous)."""

from repro.analysis.explore.instrument import TrackedCell

CODE = "WLK320"
BUDGET = 16


def build():
    stats = TrackedCell("stats.nbytes", 0)

    def producer():
        stats.add(4096)

    def drainer():
        stats.add(4096)

    return [("producer", producer), ("drainer", drainer)]
