# expect: code=WLK225
"""Seeded plan defect: a compiled reshard plan with one transfer dropped,
leaving a destination-rank hole the executor would fill with stale bytes.

``trigger`` returns the verifier's findings (unlike the lockcheck
fixtures it needs no recorder -- plancheck is a pure function)."""

from repro.analysis import plancheck
from repro.core.redistribute import CompiledPlan, even_blocks


def trigger():
    shape = (12, 8)
    plan = CompiledPlan(even_blocks(shape, 3), even_blocks(shape, 2), shape)
    # corrupt: dst rank 0 loses its transfer from src rank 1
    per_dst = list(plan.per_dst)
    per_dst[0] = tuple(t for t in per_dst[0] if t.src_rank != 1)
    object.__setattr__(plan, "per_dst", tuple(per_dst))
    return plancheck.verify_plan(plan, context="seeded coverage hole")
