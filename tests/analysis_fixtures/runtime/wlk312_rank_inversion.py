"""Seeded defect: a serve lock taken while holding a channel CV."""
from repro.analysis.lockcheck import CheckedCondition, CheckedLock


def trigger():
    cv = CheckedCondition("channel.cv:data")
    lk = CheckedLock("vol.serve:sim[0]")
    with cv:
        with lk:
            pass
