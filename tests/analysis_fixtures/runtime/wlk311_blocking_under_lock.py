"""Seeded defect: a known-blocking call entered under a fine-grained lock."""
from repro.analysis.lockcheck import CheckedLock, check_blocking


def trigger():
    lk = CheckedLock("scheduler:tick")
    with lk:
        check_blocking("Channel.get")
