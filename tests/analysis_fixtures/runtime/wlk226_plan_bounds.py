# expect: code=WLK226
"""Seeded plan defect: a transfer slab box shifted past the dataset's
global extent -- the executor would index out of bounds (or silently
wrap a negative start)."""

import dataclasses

from repro.analysis import plancheck
from repro.core.redistribute import CompiledPlan, even_blocks


def trigger():
    shape = (12, 8)
    plan = CompiledPlan(even_blocks(shape, 2), even_blocks(shape, 2), shape)
    # corrupt: shift dst rank 1's transfer one row past the extent
    bad = dataclasses.replace(
        plan.per_dst[1][0],
        global_starts=(shape[0] - plan.per_dst[1][0].shape[0] + 1, 0))
    per_dst = (plan.per_dst[0], (bad,) + plan.per_dst[1][1:])
    object.__setattr__(plan, "per_dst", per_dst)
    return plancheck.verify_plan(plan, context="seeded bounds escape")
