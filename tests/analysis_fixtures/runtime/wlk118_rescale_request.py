"""Seeded defect: a programmatic rescale request naming an unknown task."""
from repro.analysis import rules


class _EmptyGraph:
    tasks = {}

    def producers_of(self, name):
        return []


def trigger():
    rules.validate_rescale_request(_EmptyGraph(), "ghost", nslots=2)
