"""Seeded defect: two lock groups acquired in conflicting orders."""
from repro.analysis.lockcheck import CheckedLock


def trigger():
    a = CheckedLock("alpha:left")
    b = CheckedLock("beta:right")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
