"""Seeded defect: Condition.wait behind an if, not a while predicate."""
import threading


class BadWaiter:
    def __init__(self):
        self._cv = threading.Condition()
        self.ready = False

    def take(self):
        with self._cv:
            if not self.ready:
                self._cv.wait()
            return self.ready
