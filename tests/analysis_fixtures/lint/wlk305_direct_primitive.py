# expect: code=WLK305
"""Seeded lint defect: synchronization primitives constructed directly
from ``threading`` instead of through the ``analysis.lockcheck``
factories -- invisible to both the runtime lock-order recorder and the
schedule explorer."""

import threading
from threading import Condition, Semaphore as Sem


class BadChannel:
    def __init__(self):
        self._lock = threading.Lock()          # WLK305: qualified call
        self._cond = Condition()               # WLK305: from-import
        self._sem = Sem(4)                     # WLK305: aliased from-import
        self._rw = threading.RLock()           # WLK305: RLock too
        self._done = threading.Event()         # fine: Event is signaling
