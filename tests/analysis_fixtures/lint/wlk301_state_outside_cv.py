"""Seeded defect: channel state mutated outside the channel CV."""
import threading


class BadChannel:
    def __init__(self):
        self._lock = threading.Condition()
        self._queue = []

    def offer(self, item):
        self._queue.append(item)
