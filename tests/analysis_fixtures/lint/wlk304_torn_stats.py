"""Seeded defect: a stats counter bumped outside its owning lock."""
import threading


class BadStats:
    def __init__(self, stats):
        self._lock = threading.Lock()
        self.stats = stats

    def bump(self):
        self.stats.steps += 1
