"""Seeded defect: a wait loop paced by wait_quantum that never heartbeats."""


class BadLoop:
    def __init__(self, supervisor):
        self._sup = supervisor

    def drain(self, cv, done):
        with cv:
            while not done():
                cv.wait(self._sup.wait_quantum())
