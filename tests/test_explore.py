"""Tier-1 tests for Pass 3: the deterministic schedule explorer +
happens-before race detector (``repro.analysis.explore``).

Four layers:

* the **clean corpus** -- bounded exploration over every scenario in
  ``explore.scenarios.CORPUS`` completes with zero WLK3xx findings
  (the same gate the CI ``explore`` job runs);
* the **seeded-race corpus** under ``tests/analysis_fixtures/races/`` --
  each historical bug re-introduced must be FOUND within its declared
  schedule budget, with the right code, and its schedule ID must replay
  the finding deterministically;
* the **ResizableSemaphore audit** regression -- the correct resize
  survives exploration, a variant with the grow-notify dropped is caught
  as a lost wakeup;
* the **zero-cost contract** -- with ``WILKINS_EXPLORE`` unset the
  factories hand out plain ``threading`` primitives and the explorer
  hooks are no-ops.
"""

import glob
import importlib.util
import os
import threading

import pytest

from repro.analysis import lockcheck
from repro.analysis.cli import main as cli_main

HERE = os.path.dirname(os.path.abspath(__file__))
RACEDIR = os.path.join(HERE, "analysis_fixtures", "races")
RACE_FIXTURES = sorted(glob.glob(os.path.join(RACEDIR, "wlk*.py")))


@pytest.fixture
def explore_on(monkeypatch):
    monkeypatch.setenv("WILKINS_EXPLORE", "1")
    monkeypatch.delenv("WILKINS_LOCKCHECK", raising=False)


def _load(path):
    name = "_race_" + os.path.splitext(os.path.basename(path))[0]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _codes(findings):
    return {d.code for d in findings}


# ---------------------------------------------------------------------------
# clean corpus: bounded exploration, zero findings
# ---------------------------------------------------------------------------
def _corpus_names():
    from repro.analysis.explore import names
    return names()


@pytest.mark.parametrize("name", _corpus_names())
def test_clean_scenario_explores_without_findings(explore_on, name):
    from repro.analysis.explore import build_scenario, explore
    # largest measured tree (sem_resize) is ~3.7k schedules; 4000 lets
    # every scenario exhaust its frontier rather than stop at the cap
    rep = explore(build_scenario(name), scenario=name, max_schedules=4000)
    assert not rep.found, "\n" + rep.findings.render_text()
    assert rep.schedules > 1, "exploration degenerated to one schedule"
    assert rep.complete, f"{name} did not exhaust {rep.schedules} schedules"


# ---------------------------------------------------------------------------
# seeded races: every historical bug is re-found within budget
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("path", RACE_FIXTURES,
                         ids=lambda p: os.path.basename(p))
def test_race_fixture_found_within_budget(explore_on, path):
    from repro.analysis.explore import explore, replay
    mod = _load(path)
    stem = os.path.splitext(os.path.basename(path))[0]
    rep = explore(mod.build, scenario=stem, max_schedules=mod.BUDGET)
    assert rep.found, (f"{stem}: seeded bug not found in {rep.schedules} "
                       f"schedules (budget {mod.BUDGET})")
    assert mod.CODE in _codes(rep.findings), \
        f"{stem}: expected {mod.CODE}, got {sorted(_codes(rep.findings))}"
    assert rep.schedule_id, "finding carries no replayable schedule ID"
    assert rep.schedule_id.startswith(stem + "@")

    # the schedule ID replays the same finding, deterministically
    first = replay(mod.build, rep.schedule_id)
    again = replay(mod.build, rep.schedule_id)
    assert mod.CODE in _codes(first.findings), \
        f"replay lost the finding: {sorted(_codes(first.findings))}"
    assert sorted(d.code for d in first.findings) == \
        sorted(d.code for d in again.findings)
    assert first.decisions == again.decisions


@pytest.mark.parametrize("path", RACE_FIXTURES,
                         ids=lambda p: os.path.basename(p))
def test_race_fixture_discovery_is_deterministic(explore_on, path):
    from repro.analysis.explore import explore
    mod = _load(path)
    stem = os.path.splitext(os.path.basename(path))[0]
    a = explore(mod.build, scenario=stem, max_schedules=mod.BUDGET)
    b = explore(mod.build, scenario=stem, max_schedules=mod.BUDGET)
    assert a.schedule_id == b.schedule_id
    assert a.schedules == b.schedules


def test_race_finding_carries_both_stacks(explore_on):
    from repro.analysis.explore import explore
    mod = _load(os.path.join(RACEDIR, "wlk320_torn_stats.py"))
    rep = explore(mod.build, scenario="torn_stats", max_schedules=16)
    (d,) = [d for d in rep.findings if d.code == "WLK320"]
    # the message names both racing threads and where each accessed
    assert "producer" in d.message and "drainer" in d.message
    assert "wlk320_torn_stats" in d.message


# ---------------------------------------------------------------------------
# ResizableSemaphore audit regression (satellite 3)
# ---------------------------------------------------------------------------
def _sem_grow_scenario(sem_cls):
    def build():
        sem = sem_cls(1, name="channel.sem:audit")

        def holder():
            assert sem.acquire()
            # holds its slot to the end: only the resize can free the peer

        def blocked():
            assert sem.acquire(), "acquire after grow returned False"
            sem.release()

        def resizer():
            sem.resize(2)

        return [("holder", holder), ("blocked", blocked),
                ("resizer", resizer)]
    return build


def test_semaphore_resize_grow_wakes_waiters(explore_on):
    from repro.analysis.explore import explore
    from repro.core.scheduler import ResizableSemaphore
    rep = explore(_sem_grow_scenario(ResizableSemaphore),
                  scenario="sem_grow", max_schedules=128)
    assert not rep.found, "\n" + rep.findings.render_text()
    assert rep.complete


def test_semaphore_resize_without_notify_is_caught(explore_on):
    from repro.analysis.explore import explore
    from repro.core.scheduler import ResizableSemaphore

    class _SilentGrow(ResizableSemaphore):
        # the exact hazard the audit checked for: growing the limit
        # without waking blocked acquirers
        def resize(self, limit):
            with self._cond:
                self._limit = int(limit)

    rep = explore(_sem_grow_scenario(_SilentGrow),
                  scenario="sem_grow_silent", max_schedules=128)
    assert rep.found, "silent grow was not caught"
    assert "WLK322" in _codes(rep.findings), sorted(_codes(rep.findings))


def test_resizable_semaphore_shrink_races_release_real_threads():
    # the audited interleaving on REAL threads: shrink below the in-use
    # count while holders release concurrently; nobody may deadlock,
    # over-release, or leave the gauge nonzero
    from repro.core.scheduler import ResizableSemaphore
    sem = ResizableSemaphore(8, name="channel.sem:stress")
    errs = []

    def worker():
        try:
            for _ in range(200):
                assert sem.acquire(timeout=10.0)
                sem.release()
        except BaseException as e:   # noqa: BLE001 -- surface to the test
            errs.append(e)

    def resizer():
        try:
            for limit in (4, 1, 6, 2, 8) * 40:
                sem.resize(limit)
        except BaseException as e:
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    threads.append(threading.Thread(target=resizer))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not any(t.is_alive() for t in threads), "stress run wedged"
    assert not errs, errs
    assert sem.in_use == 0
    assert sem.limit == 8
    assert sem.acquire(timeout=1.0)
    sem.release()


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------
def test_cli_explore_clean_scenario(explore_on, capsys):
    assert cli_main(["explore", "--scenario", "latest_fanin"]) == 0
    out = capsys.readouterr().out
    assert "latest_fanin" in out and "clean" in out


def test_cli_explore_list(explore_on, capsys):
    assert cli_main(["explore", "--list"]) == 0
    assert "rendezvous_depth1" in capsys.readouterr().out


def test_cli_explore_json(explore_on, capsys):
    import json
    assert cli_main(["explore", "--json", "--scenario", "cow_share",
                     "--budget", "32"]) == 0
    (doc,) = [d for d in json.loads(capsys.readouterr().out)]
    assert doc["scenario"] == "cow_share"
    assert doc["found"] is False


# ---------------------------------------------------------------------------
# zero-cost contract: WILKINS_EXPLORE unset -> plain primitives, no-ops
# ---------------------------------------------------------------------------
def test_factories_plain_when_explore_unset(monkeypatch):
    monkeypatch.delenv("WILKINS_EXPLORE", raising=False)
    monkeypatch.delenv("WILKINS_LOCKCHECK", raising=False)
    assert isinstance(lockcheck.make_lock("leaf:x"), type(threading.Lock()))
    assert isinstance(lockcheck.make_condition("leaf:x"),
                      threading.Condition)
    assert isinstance(lockcheck.make_semaphore("leaf:x", 2),
                      threading.Semaphore)
    # the hooks are no-ops with no controller installed
    lockcheck.sched_point("noop", key=("x", 0), access="w")
    lockcheck.hb_publish(("x", 1))
    lockcheck.hb_consume(("x", 1))


def test_explore_primitives_fall_back_off_scenario(explore_on):
    # WILKINS_EXPLORE=1 but no controller running: the wrappers must
    # behave as real primitives on unmanaged threads
    lk = lockcheck.make_lock("leaf:x")
    with lk:
        assert lk.locked()
    assert not lk.locked()
    cv = lockcheck.make_condition("leaf:x")
    with cv:
        assert not cv.wait(timeout=0.01)
    sem = lockcheck.make_semaphore("leaf:x", 1)
    assert sem.acquire()
    sem.release()
