"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(42)


def _qkv(b, s, h, kv, d, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,d,bq,bk", [
    (1, 128, 2, 2, 32, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 128),    # GQA rep=2
    (1, 192, 8, 1, 16, 64, 128),     # MQA, ragged seq vs blocks
    (1, 96, 2, 2, 64, 128, 128),     # seq < block (degenerate single block)
])
def test_flash_attention_shapes(b, s, h, kv, d, bq, bk):
    q, k, v = _qkv(b, s, h, kv, d)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_flash_attention_noncausal_and_window():
    q, k, v = _qkv(1, 160, 4, 4, 32)
    for kwargs in ({"causal": False}, {"causal": True, "window": 48}):
        out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kwargs)
        want = ref.flash_attention_ref(q, k, v, **kwargs)
        np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5,
                                   err_msg=str(kwargs))


def test_flash_attention_bf16():
    q, k, v = _qkv(1, 128, 2, 2, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2, rtol=3e-2)


def test_flash_attention_grad_matches_oracle():
    q, k, v = _qkv(1, 128, 2, 2, 32)

    def f_k(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, block_q=64, block_k=64) ** 2)

    def f_r(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v) ** 2)

    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 16, 2, 16, 32),
    (1, 100, 4, 8, 1, 8, 32),        # ragged: s % chunk != 0
])
def test_ssd_kernel_shapes(b, s, h, p, g, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dA = -jnp.abs(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32)) * 0.1
    Bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    y1, f1 = ops.ssd_chunked_pallas(x, dA, Bm, Cm, chunk=chunk)
    y2, f2 = ssd_chunked(x, dA, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(f1, f2, atol=2e-4, rtol=2e-4)


def test_ssd_intra_chunk_vs_einsum_ref():
    b, nc, q, h, p, g, n = 1, 3, 32, 4, 16, 2, 16
    x = jnp.asarray(RNG.normal(size=(b, nc, q, h, p)), jnp.float32)
    dA = -jnp.abs(jnp.asarray(RNG.normal(size=(b, nc, q, h)), jnp.float32)) * 0.1
    Bm = jnp.asarray(RNG.normal(size=(b, nc, q, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, nc, q, g, n)), jnp.float32)
    from repro.kernels.ssd_scan import ssd_intra_chunk

    y1, s1 = ssd_intra_chunk(x, dA, Bm, Cm, interpret=True)
    y2, s2 = ref.ssd_intra_chunk_ref(x, dA, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=2e-4)


def test_ssd_with_initial_state():
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dA = -jnp.abs(jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32)) * 0.1
    Bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(b, h, n, p)), jnp.float32)
    y1, f1 = ops.ssd_chunked_pallas(x, dA, Bm, Cm, chunk=32, initial_state=s0)
    y2, f2 = ssd_chunked(x, dA, Bm, Cm, chunk=32, initial_state=s0)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(f1, f2, atol=2e-4, rtol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 12),
    rows=st.integers(1, 8),
    cols=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_blocks_property(t, rows, cols, seed):
    rng = np.random.default_rng(seed)
    n_tiles_src = 16
    src = jnp.asarray(rng.normal(size=(n_tiles_src * rows, cols)), jnp.float32)
    offs = jnp.asarray(rng.integers(0, n_tiles_src, size=t), jnp.int32)
    got = ops.pack_blocks(src, offs, tile_rows=rows)
    want = ref.pack_blocks_ref(src, offs, tile_rows=rows)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_blocks_dtypes(dtype):
    src = jnp.arange(64 * 8).reshape(64, 8).astype(dtype)
    offs = jnp.asarray([7, 0, 3], jnp.int32)
    got = ops.pack_blocks(src, offs, tile_rows=8)
    want = ref.pack_blocks_ref(src, offs, tile_rows=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 12),
    rows=st.sampled_from([8, 16]),
    cols=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_cols_property(t, rows, cols, seed):
    rng = np.random.default_rng(seed)
    n_tiles_src = 16
    src = jnp.asarray(rng.normal(size=(rows, n_tiles_src * cols)), jnp.float32)
    offs = jnp.asarray(rng.integers(0, n_tiles_src, size=t), jnp.int32)
    got = ops.pack_cols(src, offs, tile_cols=cols)
    want = ref.pack_cols_ref(src, offs, tile_cols=cols)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_cols_dtypes(dtype):
    src = jnp.arange(8 * 64).reshape(8, 64).astype(dtype)
    offs = jnp.asarray([7, 0, 3], jnp.int32)
    got = ops.pack_cols(src, offs, tile_cols=8)
    want = ref.pack_cols_ref(src, offs, tile_cols=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
