"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode-vs-forward consistency per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.models.registry import get_family
from repro.train import AdamWConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.source_len, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                       state_dtype=cfg.opt_state_dtype)
    state = init_state(KEY, cfg, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state.opt.step) == 1
    # params updated and finite
    flat = jax.tree.leaves(state.params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(KEY, cfg)
    b, s, max_len = 2, 8, 32
    batch = _batch(cfg, b, s)
    cache = fam.init_cache(cfg, b, max_len, dtype=jnp.float32)
    logits, cache = fam.prefill(params, cfg, batch, cache)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None].astype(jnp.int32)
    logits2, cache = fam.decode_step(params, cfg, tok, cache)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("family,arch", [
    ("dense", "tinyllama-1.1b"),
    ("ssm", "mamba2-2.7b"),
    ("hybrid", "zamba2-2.7b"),
])
def test_decode_matches_forward(family, arch):
    """prefill(t0..tk) + decode(t_{k+1}) == forward(t0..t_{k+1}) last logits."""
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(KEY, cfg)
    rng = np.random.default_rng(1)
    b, s = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    # full forward logits at the last position
    if family == "dense":
        from repro.models import transformer as M
        h, _, _ = M.forward(params, cfg, toks)
        from repro.models import layers as L
        full = L.unembed(params["embed"], h[:, -1:])
    elif family == "ssm":
        from repro.models import ssm as M
        h, _ = M.forward(params, cfg, toks)
        from repro.models import layers as L
        full = L.unembed(params["embed"], h[:, -1:])
    else:
        from repro.models import hybrid as M
        h, _ = M.forward(params, cfg, toks)
        from repro.models import layers as L
        full = L.unembed(params["embed"], h[:, -1:])

    # prefill on the prefix, then decode the last token
    cache = fam.init_cache(cfg, b, 32, dtype=jnp.float32)
    _, cache = fam.prefill(params, cfg, {"tokens": toks[:, :-1]}, cache)
    dec, _ = fam.decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_moe_sorted_matches_dense_oracle():
    """Grouped-dispatch MoE == dense-einsum oracle at high capacity."""
    from repro.models import layers as L

    cfg = get_config("phi3.5-moe-42b-a6.6b", reduced=True).replace(
        capacity_factor=8.0)  # no drops -> paths must agree exactly
    p = L.moe_init(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 33, cfg.d_model)),
                    jnp.float32) * 0.1
    out_d, aux_d = L.moe_dense(p, cfg, x)
    out_s, aux_s = L.moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_arctic_dense_residual_present():
    cfg = get_config("arctic-480b", reduced=True)
    fam = get_family(cfg)
    p = fam.init(KEY, cfg)
    assert "moe" in jax.tree_util.tree_structure(p["layers"]).unflatten(
        jax.tree.leaves(p["layers"]))
    assert "ffn" in p["layers"]  # dense residual branch


def test_param_counts_match_names():
    """Full configs land in the ballpark their names claim."""
    expect = {
        "arctic-480b": (430e9, 530e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "llama3.2-3b": (3.0e9, 4.2e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "phi3-mini-3.8b": (3.4e9, 4.2e9),
        "mamba2-2.7b": (2.4e9, 3.1e9),
        "internvl2-76b": (65e9, 80e9),
        "zamba2-2.7b": (2.1e9, 3.1e9),
        "whisper-base": (0.05e9, 0.16e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    a = cfg.active_param_count()
    assert 5.5e9 <= a <= 7.5e9  # the name says a6.6b


def test_shape_grid_applicability():
    long_runners = {a for a in ARCH_IDS
                    if any(s.name == "long_500k" for s in shapes_for(get_config(a)))}
    assert long_runners == {"mamba2-2.7b", "zamba2-2.7b"}
    for a in ARCH_IDS:
        names = [s.name for s in shapes_for(get_config(a))]
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_padded_vocab():
    cfg = get_config("mamba2-2.7b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    cfg2 = get_config("whisper-base")
    assert cfg2.padded_vocab % 256 == 0
