"""M->N redistribution planner/executors: property-based to the byte."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.datamodel import BlockOwnership
from repro.core.redistribute import (even_blocks, gather_to_writers, intersect,
                                     plan_redistribution, redistribute_numpy)


def test_even_blocks_cover():
    blocks = even_blocks((10, 4), 3)
    assert [b[1][0] for b in blocks] == [4, 3, 3]
    assert blocks[0][0] == (0, 0) and blocks[1][0] == (4, 0)


def test_intersect():
    a = ((0, 0), (4, 4))
    b = ((2, 2), (4, 4))
    assert intersect(a, b) == ((2, 2), (2, 2))
    assert intersect(((0, 0), (2, 2)), ((2, 2), (2, 2))) is None


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 64),
    cols=st.integers(1, 8),
    m_src=st.integers(1, 7),
    m_dst=st.integers(1, 7),
)
def test_plan_covers_every_dst_cell_once(n, cols, m_src, m_dst):
    """Every destination cell is produced by exactly one transfer (no gaps,
    no overlaps) -- the invariant LowFive's planner must satisfy."""
    src = even_blocks((n, cols), m_src)
    dst = even_blocks((n, cols), m_dst)
    plan = plan_redistribution(src, dst)
    hit = np.zeros((n, cols), dtype=int)
    for t in plan:
        slc = tuple(slice(s, s + k) for s, k in zip(t.global_starts, t.shape))
        hit[slc] += 1
    assert (hit == 1).all()


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 48),
    cols=st.integers(1, 6),
    m_src=st.integers(1, 6),
    m_dst=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_redistribute_preserves_bytes(n, cols, m_src, m_dst, seed):
    """Executing the plan reproduces the exact destination blocks."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 1000, size=(n, cols)).astype(np.int64)
    src = even_blocks(arr.shape, m_src)
    dst = even_blocks(arr.shape, m_dst)
    outs = redistribute_numpy(arr, src, dst)
    for (starts, shape), out in zip(dst, outs):
        slc = tuple(slice(s, s + k) for s, k in zip(starts, shape))
        np.testing.assert_array_equal(out, arr[slc])


def test_gather_to_writers_single():
    """io_proc=1 (LAMMPS): rank 0 owns the full global extent."""
    own = BlockOwnership()
    for r, (starts, shape) in enumerate(even_blocks((32, 3), 8)):
        own.add(r, starts, shape)
    g = gather_to_writers(own, 1)
    assert g.nranks() == 1
    assert g.blocks[0] == ((0, 0), (32, 3))


def test_gather_to_writers_subset():
    own = BlockOwnership()
    for r, (starts, shape) in enumerate(even_blocks((30,), 6)):
        own.add(r, starts, shape)
    g = gather_to_writers(own, 2)
    assert g.nranks() == 2
    total = sum(sh[0] for _, sh in g.blocks.values())
    assert total == 30


def test_reshard_jax_roundtrip():
    import jax
    from repro.core.redistribute import reshard_jax

    x = np.arange(12.0).reshape(3, 4)
    arr = jax.numpy.asarray(x)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = reshard_jax(arr, sh)
    np.testing.assert_array_equal(np.asarray(out), x)
