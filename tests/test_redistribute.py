"""M->N redistribution planner/executors: property-based to the byte."""

import threading
import time

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import Wilkins, h5
from repro.core.channel import Channel
from repro.core.datamodel import (BlockOwnership, File, reset_transport_stats,
                                  transport_stats)
from repro.core.redistribute import (CompiledPlan, PlanCache, RedistSpec,
                                     coalesce_transfers, even_blocks,
                                     execute_pack_jax, execute_pack_jax_all,
                                     gather_to_writers, intersect, plan_cache,
                                     plan_redistribution, redistribute_cached,
                                     redistribute_numpy, reset_plan_cache)


def ragged_blocks(n, nranks, rng, axis=0, shape=None):
    """Random ragged 1-D decomposition along ``axis`` (uneven cut points)."""
    shape = (n,) if shape is None else tuple(shape)
    cuts = sorted(rng.choice(n + 1, size=nranks - 1, replace=True).tolist())
    bounds = [0] + cuts + [n]
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        starts = tuple(lo if a == axis else 0 for a in range(len(shape)))
        bshape = tuple(hi - lo if a == axis else s for a, s in enumerate(shape))
        out.append((starts, bshape))
    return out


def test_even_blocks_cover():
    blocks = even_blocks((10, 4), 3)
    assert [b[1][0] for b in blocks] == [4, 3, 3]
    assert blocks[0][0] == (0, 0) and blocks[1][0] == (4, 0)


def test_intersect():
    a = ((0, 0), (4, 4))
    b = ((2, 2), (4, 4))
    assert intersect(a, b) == ((2, 2), (2, 2))
    assert intersect(((0, 0), (2, 2)), ((2, 2), (2, 2))) is None


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 64),
    cols=st.integers(1, 8),
    m_src=st.integers(1, 7),
    m_dst=st.integers(1, 7),
)
def test_plan_covers_every_dst_cell_once(n, cols, m_src, m_dst):
    """Every destination cell is produced by exactly one transfer (no gaps,
    no overlaps) -- the invariant LowFive's planner must satisfy."""
    src = even_blocks((n, cols), m_src)
    dst = even_blocks((n, cols), m_dst)
    plan = plan_redistribution(src, dst)
    hit = np.zeros((n, cols), dtype=int)
    for t in plan:
        slc = tuple(slice(s, s + k) for s, k in zip(t.global_starts, t.shape))
        hit[slc] += 1
    assert (hit == 1).all()


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 48),
    cols=st.integers(1, 6),
    m_src=st.integers(1, 6),
    m_dst=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_redistribute_preserves_bytes(n, cols, m_src, m_dst, seed):
    """Executing the plan reproduces the exact destination blocks."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 1000, size=(n, cols)).astype(np.int64)
    src = even_blocks(arr.shape, m_src)
    dst = even_blocks(arr.shape, m_dst)
    outs = redistribute_numpy(arr, src, dst)
    for (starts, shape), out in zip(dst, outs):
        slc = tuple(slice(s, s + k) for s, k in zip(starts, shape))
        np.testing.assert_array_equal(out, arr[slc])


def test_gather_to_writers_single():
    """io_proc=1 (LAMMPS): rank 0 owns the full global extent."""
    own = BlockOwnership()
    for r, (starts, shape) in enumerate(even_blocks((32, 3), 8)):
        own.add(r, starts, shape)
    g = gather_to_writers(own, 1)
    assert g.nranks() == 1
    assert g.blocks[0] == ((0, 0), (32, 3))


def test_gather_to_writers_subset():
    own = BlockOwnership()
    for r, (starts, shape) in enumerate(even_blocks((30,), 6)):
        own.add(r, starts, shape)
    g = gather_to_writers(own, 2)
    assert g.nranks() == 2
    total = sum(sh[0] for _, sh in g.blocks.values())
    assert total == 30


def test_reshard_jax_roundtrip():
    import jax
    from repro.core.redistribute import reshard_jax

    x = np.arange(12.0).reshape(3, 4)
    arr = jax.numpy.asarray(x)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = reshard_jax(arr, sh)
    np.testing.assert_array_equal(np.asarray(out), x)


# ---------------------------------------------------------------------------
# multi-axis / ragged planning properties
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 48),
    cols=st.integers(2, 12),
    m_src=st.integers(1, 5),
    m_dst=st.integers(1, 5),
)
def test_plan_covers_cross_axis(n, cols, m_src, m_dst):
    """src decomposed along axis 0, dst along axis 1: still exact cover."""
    src = even_blocks((n, cols), m_src, axis=0)
    dst = even_blocks((n, cols), m_dst, axis=1)
    hit = np.zeros((n, cols), dtype=int)
    for t in plan_redistribution(src, dst):
        slc = tuple(slice(s, s + k) for s, k in zip(t.global_starts, t.shape))
        hit[slc] += 1
    assert (hit == 1).all()


def test_plan_covers_cross_axis_seeded():
    """Deterministic cross-axis + ragged cover (runs without hypothesis)."""
    rng = np.random.default_rng(7)
    for n, cols, m_src, m_dst, src_axis, dst_axis in [
        (17, 5, 3, 2, 0, 1), (32, 8, 4, 4, 1, 0), (9, 9, 2, 5, 1, 1)
    ]:
        src = ragged_blocks([n, cols][src_axis], m_src, rng, axis=src_axis,
                            shape=(n, cols))
        dst = even_blocks((n, cols), m_dst, axis=dst_axis)
        hit = np.zeros((n, cols), dtype=int)
        for t in plan_redistribution(src, dst):
            slc = tuple(slice(s, s + k) for s, k in zip(t.global_starts, t.shape))
            hit[slc] += 1
        assert (hit == 1).all(), (n, cols, m_src, m_dst, src_axis, dst_axis)


def test_ragged_ownership_executors_byte_exact():
    """Ragged src x ragged dst: scatter executor == redistribute_numpy."""
    rng = np.random.default_rng(42)
    for trial in range(25):
        n = int(rng.integers(1, 64))
        cols = int(rng.integers(1, 7))
        src = ragged_blocks(n, int(rng.integers(1, 6)), rng, shape=(n, cols))
        dst = ragged_blocks(n, int(rng.integers(1, 6)), rng, shape=(n, cols))
        g = rng.integers(0, 1000, size=(n, cols)).astype(np.int64)
        want = redistribute_numpy(g, src, dst)
        plan = CompiledPlan(src, dst, g.shape, g.dtype)
        got_global = plan.execute_global(g)
        src_blocks = [g[s[0]:s[0] + sh[0]] for (s, sh) in src]
        got_scatter = plan.execute(src_blocks)
        for w, a, b in zip(want, got_global, got_scatter):
            np.testing.assert_array_equal(w, a)
            np.testing.assert_array_equal(w, b)


def test_scatter_executor_writes_into_preallocated_blocks():
    g = np.arange(40.0).reshape(10, 4)
    src = even_blocks(g.shape, 5)
    dst = even_blocks(g.shape, 2)
    plan = CompiledPlan(src, dst, g.shape, g.dtype)
    out = [np.full(sh, -1.0) for (_, sh) in dst]
    res = plan.execute_global(g, out=out)
    assert res[0] is out[0] and res[1] is out[1]  # no reallocation
    np.testing.assert_array_equal(out[0], g[:5])
    np.testing.assert_array_equal(out[1], g[5:])


def test_coalescing_merges_contiguous_runs():
    from repro.core.redistribute import Transfer

    # 4 src blocks feeding 2 dst blocks: per-(src,dst) descriptors stay
    # separate (scatter reads per-source blocks) but the global-buffer runs
    # coalesce across src ranks -- one contiguous copy per dst block.
    src = even_blocks((8, 4), 4)
    dst = even_blocks((8, 4), 2)
    plan = CompiledPlan(src, dst, (8, 4), np.float32)
    assert [len(s) for s in plan.per_dst] == [2, 2]
    assert [len(s) for s in plan.per_dst_runs] == [1, 1]
    assert plan.per_dst_runs[0][0] == Transfer(-1, 0, (0, 0), (4, 4))
    assert plan.per_dst_runs[1][0] == Transfer(-1, 1, (4, 0), (4, 4))
    # same dst fed by two adjacent pieces of one src block merges either way
    parts = [Transfer(0, 0, (0, 0), (2, 4)), Transfer(0, 0, (2, 0), (3, 4))]
    assert coalesce_transfers(parts) == [Transfer(0, 0, (0, 0), (5, 4))]
    # different dst ranks never merge
    apart = [Transfer(0, 0, (0, 0), (2, 4)), Transfer(0, 1, (2, 0), (3, 4))]
    assert len(coalesce_transfers(apart, ignore_src=True)) == 2


def test_aligned_detector():
    src = even_blocks((12, 3), 3)
    assert CompiledPlan(src, src, (12, 3), np.int32).aligned
    assert CompiledPlan(src, src, (12, 3), np.int32).identity
    off = even_blocks((12, 3), 4)
    p = CompiledPlan(src, off, (12, 3), np.int32)
    assert not p.aligned and not p.identity
    # aligned but not identity: dst is a permutation-compatible single-block
    assert CompiledPlan([((0, 0), (12, 3))], [((0, 0), (12, 3))],
                        (12, 3), np.int32).aligned


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_hit_and_invalidation():
    c = PlanCache(maxsize=8)
    src = even_blocks((16, 2), 4)
    dst = even_blocks((16, 2), 2)
    p1 = c.get(src, dst, (16, 2), np.float64)
    p2 = c.get(src, dst, (16, 2), np.float64)
    assert p1 is p2
    assert c.snapshot()["hits"] == 1 and c.snapshot()["misses"] == 1
    # different dtype / shape / blocks are different plans
    assert c.get(src, dst, (16, 2), np.float32) is not p1
    assert c.get(src, dst[::-1], (16, 2), np.float64) is not p1
    assert c.snapshot()["misses"] == 3


def test_plan_cache_lru_eviction():
    c = PlanCache(maxsize=2)
    shapes = [(8, 1), (9, 1), (10, 1)]
    plans = [c.get(even_blocks(s, 2), even_blocks(s, 2), s, np.int8)
             for s in shapes]
    assert c.snapshot()["evictions"] == 1 and len(c) == 2
    # (8,1) was evicted: re-getting it misses and recompiles
    again = c.get(even_blocks((8, 1), 2), even_blocks((8, 1), 2), (8, 1), np.int8)
    assert again is not plans[0]
    # (10,1) is still hot
    assert c.get(even_blocks((10, 1), 2), even_blocks((10, 1), 2),
                 (10, 1), np.int8) is plans[2]


def test_redistribute_cached_matches_uncached():
    reset_plan_cache()
    g = np.arange(60).reshape(12, 5)
    src = even_blocks(g.shape, 3)
    dst = even_blocks(g.shape, 4)
    for _ in range(3):
        outs = redistribute_cached(g, src, dst)
        for w, a in zip(redistribute_numpy(g, src, dst), outs):
            np.testing.assert_array_equal(w, a)
    snap = plan_cache().snapshot()
    assert snap["hits"] == 2 and snap["misses"] == 1


# ---------------------------------------------------------------------------
# JAX pack executor (kernels/pack.py lowering)
# ---------------------------------------------------------------------------
def test_pack_executor_matches_numpy_scatter():
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    for rows, cols, m_src, m_dst, tile_rows in [
        (64, 8, 4, 2, 8), (40, 16, 3, 3, 8), (37, 8, 2, 5, 4)
    ]:
        g = rng.normal(size=(rows, cols)).astype(np.float32)
        src = even_blocks(g.shape, m_src)
        dst = even_blocks(g.shape, m_dst)
        plan = CompiledPlan(src, dst, g.shape, g.dtype)
        want = plan.execute_global(g)
        gj = jnp.asarray(g)
        for r in range(m_dst):
            got = np.asarray(execute_pack_jax(plan, r, gj, tile_rows=tile_rows))
            np.testing.assert_array_equal(got, want[r])


def test_pack_tiles_cached_on_plan():
    plan = CompiledPlan(even_blocks((32, 8), 2), even_blocks((32, 8), 4),
                        (32, 8), np.float32)
    t1, s1 = plan.pack_tiles(1, 8)
    t2, s2 = plan.pack_tiles(1, 8)
    assert t1 is t2 and s1 is s2  # lowered once, cached on the plan


# ---------------------------------------------------------------------------
# channel integration: slab shipping, aligned views, spill roundtrip
# ---------------------------------------------------------------------------
def _mxn_yaml(n_prod, n_cons, cons_ranks, extra=""):
    return f"""
tasks:
  - func: producer
    taskCount: {n_prod}
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    taskCount: {n_cons}
    nprocs: {cons_ranks}
    inports:
      - filename: o.h5
        redistribute: 1
        {extra}
        dsets: [{{name: /g, memory: 1}}]
"""


def _owned(n, m):
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), m)):
        own.add(r, s, sh)
    return own


def test_mxn_channel_ships_only_owned_slabs():
    n, steps = 512, 3
    got = []
    lock = threading.Lock()

    def producer():
        own = _owned(n, 4)
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(n, dtype=np.float64) + t,
                                 ownership=own)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            d = f["/g"]
            with lock:
                got.append((tuple(d.attrs["redist_box_starts"]), d.shape,
                            np.asarray(d[:])))

    reset_plan_cache()
    reset_transport_stats()
    w = Wilkins(_mxn_yaml(4, 2, 2), {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    # 4 channels x steps serves, each shipping HALF the dataset
    assert rep.total_served == 4 * steps
    assert rep.total_bytes_moved == 4 * steps * (n // 2) * 8
    s = transport_stats().snapshot()
    assert s["redist_baseline_bytes"] == 2 * s["redist_shipped_bytes"]
    assert plan_cache().snapshot()["misses"] == 1  # one compile for the edge
    for starts, shape, data in got:
        assert shape == (n // 2,)
        base = data[0] - starts[0]  # payload + t offset
        np.testing.assert_array_equal(
            data, np.arange(starts[0], starts[0] + n // 2) + base)


def test_mxn_consumer_gets_per_rank_ownership():
    n = 64
    boxes = []

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(n, dtype=np.float64),
                             ownership=_owned(n, 4))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            boxes.append(dict(f["/g"].ownership.blocks))

    w = Wilkins(_mxn_yaml(1, 1, 2), {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    # nslots=1, nranks=2: the instance owns the whole extent split in two
    assert boxes == [{0: ((0,), (32,)), 1: ((32,), (32,))}]


def test_aligned_decomposition_ships_views_zero_copy():
    n = 256

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.zeros(n), ownership=_owned(n, 2))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            assert f["/g"].shape == (n,)  # whole extent: a view, not a slab

    reset_plan_cache()
    reset_transport_stats()
    w = Wilkins(_mxn_yaml(1, 1, 2), {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    s = transport_stats().snapshot()
    assert s["redist_aligned"] == 1 and s["redist_slabs"] == 0
    # the view's payload bytes still count as shipped; zero bytes were COPIED
    assert s["redist_shipped_bytes"] == s["redist_baseline_bytes"] == n * 8
    assert s["bytes_copied"] == n * 8  # only the create_dataset snapshot


def test_redistribute_through_file_transport(tmp_path):
    """Slab payloads survive the spill container (ownership + attrs)."""
    n = 128
    got = []

    yaml = f"""
tasks:
  - func: producer
    taskCount: 2
    outports:
      - filename: o.h5
        dsets: [{{name: /g, file: 1, memory: 0}}]
  - func: consumer
    taskCount: 2
    nprocs: 1
    inports:
      - filename: o.h5
        redistribute: 1
        dsets: [{{name: /g, file: 1, memory: 0}}]
"""
    lock = threading.Lock()

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(n, dtype=np.float64),
                             ownership=_owned(n, 2))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            d = f["/g"]
            with lock:
                got.append((tuple(d.attrs["redist_box_starts"]),
                            np.asarray(d[:]), dict(d.ownership.blocks)))

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer},
                spill_dir=str(tmp_path))
    w.run(timeout=60)
    assert sorted(s[0] for s, _, _ in got) == [0, 64]
    for (s0,), data, blocks in got:
        np.testing.assert_array_equal(data, np.arange(s0, s0 + 64))
        assert blocks == {0: ((s0,), (64,))}


def test_redist_slab_is_cow_protected():
    """A consumer writing its slab must not corrupt the producer's buffer."""
    f = File("o.h5")
    src = f.create_dataset("/g", data=np.arange(16.0))
    ch = Channel("c", ("p", 0), ("c", 0), "o.h5", ["/g"],
                 redistribute=RedistSpec(axis=0, nslots=2, slot=1, nranks=1))
    out = ch.filter_file(f)
    slab = out["/g"]
    assert slab.shape == (8,)
    assert np.shares_memory(slab.read_direct(), src.read_direct())
    slab[0] = -1.0  # CoW: copies the slab only
    assert slab[0] == -1.0 and src[8] == 8.0
    assert not np.shares_memory(slab.read_direct(), src.read_direct())


def test_legacy_mode_honors_redistribute_contract():
    """zero_copy=False still ships only the owned slab (eagerly copied)."""
    f = File("o.h5")
    src = f.create_dataset("/g", data=np.arange(16.0))
    ch = Channel("c", ("p", 0), ("c", 0), "o.h5", ["/g"], zero_copy=False,
                 redistribute=RedistSpec(axis=0, nslots=2, slot=1, nranks=1))
    reset_transport_stats()
    out = ch.filter_file(f)
    slab = out["/g"]
    assert slab.shape == (8,)
    assert tuple(slab.attrs["redist_box_starts"]) == (8,)
    assert slab.ownership.blocks == {0: ((8,), (8,))}
    assert not np.shares_memory(slab.read_direct(), src.read_direct())
    np.testing.assert_array_equal(slab[:], np.arange(8.0, 16.0))
    # legacy copies eagerly -- but only the slab's bytes, not the whole file
    assert transport_stats().snapshot()["bytes_copied"] == 8 * 8


def test_pack_all_pads_once_and_matches_per_rank():
    import jax.numpy as jnp

    g = np.arange(37 * 8, dtype=np.float32).reshape(37, 8)  # ragged rows
    plan = CompiledPlan(even_blocks(g.shape, 3), even_blocks(g.shape, 4),
                        g.shape, g.dtype)
    want = plan.execute_global(g)
    got = execute_pack_jax_all(plan, jnp.asarray(g), tile_rows=8)
    assert len(got) == 4
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))


def test_redist_axis_and_subset_writers():
    """redistribute: {axis: 1} decomposes columns; nwriters collapses ranks."""
    n = 32
    got = []

    yaml = f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    nprocs: 4
    nwriters: 2
    inports:
      - filename: o.h5
        redistribute: {{axis: 1}}
        dsets: [{{name: /g, memory: 1}}]
"""

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(4 * n, dtype=np.float64).reshape(4, n))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            got.append(dict(f["/g"].ownership.blocks))

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    # io_procs=2 subset writers along axis 1: two column blocks, not four
    assert got == [{0: ((0, 0), (4, 16)), 1: ((0, 16), (4, 16))}]


# ---------------------------------------------------------------------------
# column-tile pack lowering (axis-1 decompositions on the kernel path)
# ---------------------------------------------------------------------------
def test_pack_mode_detection():
    rowp = CompiledPlan(even_blocks((32, 8), 4), even_blocks((32, 8), 2),
                        (32, 8), np.float32)
    assert rowp.pack_mode == "rows"
    colp = CompiledPlan(even_blocks((32, 8), 4, axis=1),
                        even_blocks((32, 8), 2, axis=1), (32, 8), np.float32)
    assert colp.pack_mode == "cols"
    # cross-axis src: dst runs coalesce across src ranks into full-height
    # column slabs, so the exchange still lowers to the column kernel
    cross = CompiledPlan(even_blocks((32, 8), 4, axis=0),
                         even_blocks((32, 8), 2, axis=1), (32, 8), np.float32)
    assert cross.pack_mode == "cols"
    # a 2-D quadrant tiling is neither full-width nor full-height
    quads = [((0, 0), (8, 8)), ((0, 8), (8, 8)),
             ((8, 0), (8, 8)), ((8, 8), (8, 8))]
    tiled = CompiledPlan([((0, 0), (16, 16))], quads, (16, 16), np.float32)
    assert tiled.pack_mode is None
    oned = CompiledPlan(even_blocks((32,), 4), even_blocks((32,), 2),
                        (32,), np.float32)
    assert oned.pack_mode is None


def test_pack_executor_cols_matches_numpy_scatter():
    import jax.numpy as jnp

    rng = np.random.default_rng(11)
    for rows, cols, m_src, m_dst, tile in [
        (8, 64, 4, 2, 8), (16, 40, 3, 3, 8), (8, 37, 2, 5, 4)
    ]:
        g = rng.normal(size=(rows, cols)).astype(np.float32)
        src = even_blocks(g.shape, m_src, axis=1)
        dst = even_blocks(g.shape, m_dst, axis=1)
        plan = CompiledPlan(src, dst, g.shape, g.dtype)
        assert plan.pack_mode == "cols"
        want = plan.execute_global(g)
        gj = jnp.asarray(g)
        for r in range(m_dst):
            got = np.asarray(execute_pack_jax(plan, r, gj, tile_rows=tile))
            np.testing.assert_array_equal(got, want[r])
        allr = execute_pack_jax_all(plan, jnp.asarray(g), tile_rows=tile)
        for w, a in zip(want, allr):
            np.testing.assert_array_equal(w, np.asarray(a))


def test_pack_executor_rejects_unlowerable_plans():
    import jax.numpy as jnp

    quads = [((0, 0), (8, 8)), ((0, 8), (8, 8)),
             ((8, 0), (8, 8)), ((8, 8), (8, 8))]
    plan = CompiledPlan([((0, 0), (16, 16))], quads, (16, 16), np.float32)
    with pytest.raises(ValueError, match="not pack-kernel lowerable"):
        execute_pack_jax(plan, 0, jnp.zeros((16, 16), jnp.float32))


def test_pack_executor_cross_axis_exchange():
    """src along axis 0, dst along axis 1: runs coalesce to full-height
    column slabs and the exchange stays on the kernel path."""
    import jax.numpy as jnp

    g = np.arange(32 * 12, dtype=np.float32).reshape(32, 12)
    plan = CompiledPlan(even_blocks(g.shape, 4, axis=0),
                        even_blocks(g.shape, 3, axis=1), g.shape, g.dtype)
    want = plan.execute_global(g)
    got = execute_pack_jax_all(plan, jnp.asarray(g), tile_rows=4)
    for w, a in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(a))


def test_execute_ranks_restriction_matches_full():
    g = np.arange(80.0).reshape(16, 5)
    src = even_blocks(g.shape, 4)
    dst = even_blocks(g.shape, 3)
    plan = CompiledPlan(src, dst, g.shape, g.dtype)
    full = plan.execute_global(g)
    sub = plan.execute_global(g, ranks=[2, 0])
    np.testing.assert_array_equal(sub[0], full[2])
    np.testing.assert_array_equal(sub[1], full[0])
    src_blocks = [g[s[0]:s[0] + sh[0]] for (s, sh) in src]
    sub2 = plan.execute(src_blocks, ranks=[1])
    np.testing.assert_array_equal(sub2[0], full[1])


# ---------------------------------------------------------------------------
# async slab prefetch (payload futures on redistributing channels)
# ---------------------------------------------------------------------------
def test_prefetch_default_and_yaml_knob():
    from repro.core import Wilkins

    w = Wilkins(_mxn_yaml(2, 2, 1), {"producer": lambda: None,
                                     "consumer": lambda: None})
    assert all(c.prefetch for c in w.channels)      # redistribute => on
    w2 = Wilkins(_mxn_yaml(2, 2, 1, extra="prefetch: 0"),
                 {"producer": lambda: None, "consumer": lambda: None})
    assert not any(c.prefetch for c in w2.channels)  # knob overrides
    plain = Channel("p", ("p", 0), ("c", 0), "o.h5", ["/g"])
    assert not plain.prefetch                        # no spec => off


@pytest.mark.slow
def test_prefetch_channel_serves_futures_byte_exact():
    """Payloads prepared on the executor arrive bit-exact, with bytes_moved
    and hit/miss accounting landing by delivery time."""
    n, steps = 256, 4
    got = []
    lock = threading.Lock()

    def producer():
        own = _owned(n, 4)
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.arange(n, dtype=np.float64) + t,
                                 ownership=own)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            time.sleep(0.01)  # give the executor room to finish the NEXT prep
            with lock:
                got.append(np.asarray(f["/g"][:]))

    from repro.core import Wilkins
    reset_plan_cache()
    reset_transport_stats()
    w = Wilkins(_mxn_yaml(4, 2, 2), {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    s = transport_stats().snapshot()
    assert rep.total_served == 4 * steps
    # every served payload was a future and was resolved at delivery
    assert s["prefetch_hits"] + s["prefetch_misses"] == 4 * steps
    assert s["prefetch_prepared_s"] > 0.0
    assert rep.total_bytes_moved == 4 * steps * (n // 2) * 8
    for data in got:
        assert data.shape == (n // 2,)


def test_prefetch_disabled_records_nothing():
    n = 64

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(n, dtype=np.float64),
                             ownership=_owned(n, 2))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break

    from repro.core import Wilkins
    reset_transport_stats()
    w = Wilkins(_mxn_yaml(2, 2, 1, extra="prefetch: 0"),
                {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    s = transport_stats().snapshot()
    assert s["prefetch_hits"] == s["prefetch_misses"] == 0
    assert s["prefetch_prepared_s"] == 0.0
    assert rep.total_bytes_moved > 0     # sync path still accounts in offer


@pytest.mark.slow
def test_prefetch_through_file_transport(tmp_path):
    """Spill writes also ride the executor; payloads still load correctly."""
    n = 128
    got = []
    lock = threading.Lock()

    yaml = """
tasks:
  - func: producer
    taskCount: 2
    outports:
      - filename: o.h5
        dsets: [{name: /g, file: 1, memory: 0}]
  - func: consumer
    taskCount: 2
    nprocs: 1
    inports:
      - filename: o.h5
        redistribute: 1
        dsets: [{name: /g, file: 1, memory: 0}]
"""

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(n, dtype=np.float64),
                             ownership=_owned(n, 2))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            with lock:
                got.append(np.asarray(f["/g"][:]))

    from repro.core import Wilkins
    reset_transport_stats()
    w = Wilkins(yaml, {"producer": producer, "consumer": consumer},
                spill_dir=str(tmp_path))
    w.run(timeout=60)
    assert len(got) == 2
    total = sorted(float(v[0]) for v in got)
    assert total == [0.0, 64.0]
    s = transport_stats().snapshot()
    assert s["prefetch_hits"] + s["prefetch_misses"] == 2


def test_prefetch_prepare_error_reaches_consumer():
    """An exception inside async payload prep must surface in get(), not
    vanish in the executor."""
    from repro.core.channel import Channel as Ch

    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(8.0))
    ch = Ch("c", ("p", 0), ("c", 0), "o.h5", ["/g"],
            redistribute=RedistSpec(axis=0, nslots=2, slot=1, nranks=1))
    ch.filter_file = lambda _f: (_ for _ in ()).throw(RuntimeError("prep boom"))
    assert ch.offer(f)
    with pytest.raises(RuntimeError, match="prep boom"):
        ch.get(timeout=5)


def test_prefetch_prepare_error_unblocks_producer():
    """A failed async prep must not leave the producer parked forever in the
    rendezvous wait: delivery marks the channel done, offer stops serving."""
    from repro.core.channel import Channel as Ch

    f = File("o.h5")
    f.create_dataset("/g", data=np.arange(8.0))
    ch = Ch("c", ("p", 0), ("c", 0), "o.h5", ["/g"],
            redistribute=RedistSpec(axis=0, nslots=2, slot=0, nranks=1))
    ch.filter_file = lambda _f: (_ for _ in ()).throw(OSError("disk full"))
    assert ch.offer(f)                       # queue slot taken by the future
    with pytest.raises(OSError, match="disk full"):
        ch.get(timeout=5)
    # queue_depth=1 and the slot was consumed: a hung channel would block
    # here forever; the failure containment makes offer a no-op instead
    assert ch.offer(f) is False
    assert ch.get(timeout=5) is None         # done, not hung
