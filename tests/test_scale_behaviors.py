"""Scale behaviours claimed in DESIGN: straggler mitigation via `latest`
flow control, and elastic ensemble re-sizing via re-matching."""

import threading
import time

import numpy as np

from repro.core import Wilkins, WorkflowGraph, h5


def test_latest_mitigates_straggler_instance():
    """An NxN ensemble with one slow producer: under `latest` the fast pairs
    finish at their own rate and the consumer of the straggler just sees
    fewer (fresher) snapshots -- nobody waits on the slow instance."""
    yaml = """
tasks:
  - func: sim
    taskCount: 3
    outports:
      - filename: o.h5
        dsets: [{name: /x, memory: 1}]
  - func: ana
    taskCount: 3
    inports:
      - filename: o.h5
        io_freq: -1
        dsets: [{name: /x, memory: 1}]
"""
    lock = threading.Lock()
    got = {0: 0, 1: 0, 2: 0}

    def sim(comm):
        slow = comm.instance == 1
        for t in range(6):
            time.sleep(0.12 if slow else 0.01)   # instance 1 straggles 12x
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/x", data=np.array([t]))

    def ana(comm):
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            time.sleep(0.02)
            with lock:
                got[comm.instance] += 1

    w = Wilkins(yaml, {"sim": sim, "ana": ana})
    t0 = time.monotonic()
    rep = w.run(timeout=60)
    wall = time.monotonic() - t0
    # wall time tracks the straggler's own compute (~6*0.12) not 3x it; the
    # fast pairs were never serialized behind instance 1
    assert wall < 2.0
    assert got[0] >= 1 and got[2] >= 1
    assert rep.total_dropped >= 1      # straggler/fast mismatch absorbed


def test_elastic_ensemble_resize_rematches():
    """Scaling an ensemble is one YAML field: the graph re-matches ports and
    re-plans instance pairing with no task-code changes (elastic resize)."""
    def doc(n_prod, n_cons):
        return {
            "tasks": [
                {"func": "p", "taskCount": n_prod,
                 "outports": [{"filename": "o.h5",
                               "dsets": [{"name": "/g", "memory": 1}]}]},
                {"func": "c", "taskCount": n_cons,
                 "inports": [{"filename": "o.h5",
                              "dsets": [{"name": "/g", "memory": 1}]}]},
            ]
        }

    g1 = WorkflowGraph.from_yaml(doc(4, 2))
    g2 = WorkflowGraph.from_yaml(doc(8, 4))      # scaled up
    assert len(g1.edges) == len(g2.edges) == 1
    assert g1.edges[0].instance_links(4, 2) == [(0, 0), (1, 1), (2, 0), (3, 1)]
    links2 = g2.edges[0].instance_links(8, 4)
    assert len(links2) == 8
    assert {c for _, c in links2} == {0, 1, 2, 3}   # all consumers used

    # and the scaled workflow actually runs
    counts = []
    lock = threading.Lock()

    def p():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(8))

    def c():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            with lock:
                counts.append(1)

    w = Wilkins(doc(8, 4), {"p": p, "c": c})
    w.run(timeout=30)
    assert len(counts) == 8
