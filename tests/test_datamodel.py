"""HDF5-style data model: tree ops, hyperslabs, container I/O, glob match."""

import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.datamodel import (BlockOwnership, Dataset, File, Group,
                                  match_file, match_path)


def test_tree_and_paths():
    f = File("a.h5")
    ds = f.create_dataset("/g1/g2/data", data=np.ones((4, 5)))
    assert ds.path == "/g1/g2/data"
    assert f["/g1/g2/data"] is ds
    assert "/g1/g2" in f and "/g1/zzz" not in f
    assert isinstance(f["/g1"], Group)
    with pytest.raises(KeyError):
        f["/nope"]


def test_hyperslab_read_write():
    f = File("a.h5")
    ds = f.create_dataset("/d", shape=(8, 8), dtype=np.float32)
    block = np.arange(6, dtype=np.float32).reshape(2, 3)
    ds.write_slab((2, 4), block)
    np.testing.assert_array_equal(ds.select((2, 4), (2, 3)), block)
    assert ds.nbytes == 8 * 8 * 4


def test_container_roundtrip(tmp_path):
    f = File("snap.h5")
    d1 = f.create_dataset("/grid", data=np.arange(100, dtype=np.uint64))
    d1.attrs["timestep"] = 3
    own = BlockOwnership()
    own.add(0, (0,), (50,))
    own.add(1, (50,), (50,))
    d1.ownership = own
    f.create_dataset("/p/pos", data=np.ones((10, 3), np.float32))

    path = f.save(str(tmp_path))
    g = File.load(path)
    np.testing.assert_array_equal(g["/grid"][:], np.arange(100, dtype=np.uint64))
    assert g["/grid"].attrs["timestep"] == 3
    assert g["/grid"].ownership.blocks[1] == ((50,), (50,))
    assert g.total_bytes() == f.total_bytes()


def test_copy_meta_only():
    f = File("x.h5")
    f.create_dataset("/a/b", data=np.ones((4,)))
    m = f.copy_meta_only()
    assert m["/a/b"].shape == (4,)
    # structural copy: data buffers are fresh
    assert not np.shares_memory(m["/a/b"].read_direct(), f["/a/b"].read_direct())


@pytest.mark.parametrize("pattern,path,want", [
    ("/group1/grid", "/group1/grid", True),
    ("/group1/*", "/group1/grid", True),
    ("/particles/*", "/particles/pos/value", True),   # prefix semantics
    ("/group1/grid", "/group1/particles", False),
    ("/group1", "/group1/grid", True),                # group names subtree
    ("*", "/anything", True),
])
def test_match_path(pattern, path, want):
    assert match_path(pattern, path) is want


@pytest.mark.parametrize("pattern,name,want", [
    ("outfile.h5", "outfile.h5", True),
    ("*.h5", "outfile.h5", True),
    ("plt*.h5", "plt00010.h5", True),
    ("plt*.h5", "out.h5", False),
])
def test_match_file(pattern, name, want):
    assert match_file(pattern, name) is want


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["a", "b", "c", "dd"]), min_size=1, max_size=4))
def test_match_path_reflexive(parts):
    """Any concrete path matches itself (property)."""
    p = "/" + "/".join(parts)
    assert match_path(p, p)


# ---------------------------------------------------------------------------
# CoW share-count thread safety
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_share_race_view_vs_cow_write():
    """Racing ``view()`` against a CoW write must never tear the
    (share, buffer) pair: a view taken mid-materialization could otherwise
    alias the writer's fresh private buffer while holding a stale (or
    fresh-but-unincremented) ``_Share``, so writes leak across the view
    boundary.  Fails before the atomic-capture fix in Dataset.view."""
    import sys
    import threading

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    try:
        for trial in range(60):
            f = File("race.h5")
            src = f.create_dataset("/g", data=np.zeros(32))
            views = []
            gate = threading.Barrier(3)

            def viewer():
                gate.wait()
                for _ in range(150):
                    views.append(src.view())

            def writer():
                gate.wait()
                for i in range(150):
                    src[0] = float(i + 1)  # CoW materialize + share swap

            ts = [threading.Thread(target=viewer), threading.Thread(target=writer)]
            for t in ts:
                t.start()
            gate.wait()
            for t in ts:
                t.join()
            # CoW invariant: a write through any view must never reach src.
            snap = np.array(src.read_direct())
            for v in views:
                v[0] = -1.0
            np.testing.assert_array_equal(np.asarray(src.read_direct()), snap)
            # and every materialized view is now truly private
            for v in views:
                assert not np.shares_memory(v.read_direct(), src.read_direct())
    finally:
        sys.setswitchinterval(old)
