"""Training substrate: optimizer, accumulation, compression, checkpointing,
data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import (AdamWConfig, AsyncCheckpointer, DataConfig,
                         SyntheticCorpus, init_state, load_pytree,
                         make_batch_iter, make_train_step, restore_latest,
                         save_pytree)
from repro.train.optim import adamw_init, adamw_update, cosine_lr, global_norm

CFG = get_config("tinyllama-1.1b", reduced=True)
OCFG = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
KEY = jax.random.PRNGKey(0)


def _batch(step=0, b=8, s=32):
    c = SyntheticCorpus(DataConfig(vocab=CFG.vocab, seq_len=s, global_batch=b))
    return {k: jnp.asarray(v) for k, v in c.batch(step).items()}


def test_loss_decreases():
    state = init_state(KEY, CFG, OCFG)
    step = jax.jit(make_train_step(CFG, OCFG))
    losses = []
    for i in range(12):
        state, m = step(state, _batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_accum_equivalent_to_full_batch():
    """accum_steps=2 must match the full-batch gradient step closely."""
    s0 = init_state(KEY, CFG, OCFG)
    b = _batch(0)
    s1, m1 = jax.jit(make_train_step(CFG, OCFG, accum_steps=1))(s0, b)
    s2, m2 = jax.jit(make_train_step(CFG, OCFG, accum_steps=2))(s0, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   atol=5e-4, rtol=5e-3)


@pytest.mark.slow
def test_compressed_grads_close_to_exact():
    """int8 error-feedback compression stays near the exact update."""
    s0 = init_state(KEY, CFG, OCFG)
    b = _batch(0)
    s1, _ = jax.jit(make_train_step(CFG, OCFG, accum_steps=2))(s0, b)
    s2, _ = jax.jit(make_train_step(CFG, OCFG, accum_steps=2,
                                    compress_grads=True))(s0, b)
    n_exact = float(global_norm(s1.params))
    diffs = jax.tree.map(
        lambda a, c: np.abs(np.asarray(a, np.float32) - np.asarray(c, np.float32)).max(),
        s1.params, s2.params)
    assert max(jax.tree.leaves(diffs)) < 0.05 * max(n_exact, 1.0)


def test_cosine_lr_schedule():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_lr(c, jnp.asarray(0))) == 0.0
    assert abs(float(cosine_lr(c, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(cosine_lr(c, jnp.asarray(100))) - 0.1) < 1e-6


def test_adamw_decays_matrices_only():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    st = adamw_init(params, AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0))
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(params, zero_g, st,
                             AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0))
    assert float(new["w"][0, 0]) < 1.0      # decayed
    assert float(new["scale"][0]) == 1.0    # not decayed


def test_checkpoint_roundtrip(tmp_path):
    state = init_state(KEY, CFG, OCFG)
    path = os.path.join(tmp_path, "s.ckpt")
    save_pytree(jax.tree.map(np.asarray, state), path)
    back = load_pytree(path, jax.tree.map(np.asarray, state))
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, state)),
                    jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_async_checkpointer_retention_and_resume(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    state = {"x": np.arange(4.0)}
    for step in (10, 20, 30):
        ck.save(step, {"x": np.arange(4.0) + step}, block=True)
    assert ck.latest_step() == 30
    files = [f for f in os.listdir(tmp_path) if f.endswith(".ckpt")]
    assert len(files) == 2  # retention
    got = restore_latest(str(tmp_path), state)
    assert got[0] == 30
    np.testing.assert_array_equal(got[1]["x"], np.arange(4.0) + 30)


def test_checkpoint_atomicity(tmp_path):
    """A truncated .tmp never shadows a good checkpoint."""
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    ck.save(1, {"x": np.ones(3)}, block=True)
    # simulate a crash mid-write of the next checkpoint
    with open(os.path.join(tmp_path, "step_00000002.ckpt.tmp"), "wb") as f:
        f.write(b"garbage")
    got = restore_latest(str(tmp_path), {"x": np.ones(3)})
    assert got[0] == 1  # LATEST still points at the good one


def test_data_determinism_and_structure():
    dcfg = DataConfig(vocab=101, seq_len=64, global_batch=4, seed=7)
    c1, c2 = SyntheticCorpus(dcfg), SyntheticCorpus(dcfg)
    b1, b2 = c1.batch(5), c2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 101).all()
    # labels are next-token shifted
    full1 = c1.batch(3)
    assert (full1["tokens"][:, 1:] == full1["labels"][:, :-1]).all()
    # different steps differ
    assert not np.array_equal(c1.batch(0)["tokens"], c1.batch(1)["tokens"])


def test_prefetch_iterator_order():
    dcfg = DataConfig(vocab=11, seq_len=8, global_batch=2)
    steps = [s for s, _ in make_batch_iter(dcfg, num_steps=5, prefetch=True)]
    assert steps == [0, 1, 2, 3, 4]
