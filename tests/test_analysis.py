"""Tier-1 tests for the pre-run analyzers (``repro.analysis``).

Three layers:

* the **seeded-defect corpus** under ``tests/analysis_fixtures/`` -- one
  fixture per registry code, each asserted to be flagged with the right
  code anchored at the right task/port (the registry-completeness test
  makes "new code without a fixture" a test failure);
* **zero-findings** assertions -- every embedded example workflow and the
  whole ``src/repro`` tree must come back clean, so the analyzer gates CI
  without drowning it in noise;
* the **diagnostics plumbing** -- suppressions (both spellings), renderers,
  CLI exit codes, and the runtime lock-checker's recorder.
"""

import glob
import importlib.util
import json
import os
import re
import sys

import pytest

from repro.analysis import astlint, lockcheck, rules, workflow
from repro.analysis.cli import main as cli_main
from repro.analysis.diagnostics import (Diagnostic, Findings, Location,
                                        REGISTRY, Severity, line_suppressions)
from repro.analysis.rules import WorkflowValidationError

HERE = os.path.dirname(os.path.abspath(__file__))
FIXDIR = os.path.join(HERE, "analysis_fixtures")
REPO = os.path.dirname(HERE)
SRC_TREE = os.path.join(REPO, "src", "repro")
EXAMPLES = sorted(glob.glob(os.path.join(REPO, "examples", "*.py")))

_FIX_RE = re.compile(r"wlk(\d+)")


def _fixture_code(path):
    return "WLK" + _FIX_RE.match(os.path.basename(path)).group(1)


def _fixtures(pattern):
    return sorted(glob.glob(os.path.join(FIXDIR, pattern)))


def _expectations(path):
    """Parse the ``# expect: task=... port=...`` header of a fixture."""
    with open(path) as f:
        first = f.readline()
    out = {}
    m = re.search(r"#\s*expect:(.*)", first)
    if m:
        for kv in m.group(1).split():
            k, _, v = kv.partition("=")
            out[k] = v
    return out


def _load_trigger(path):
    name = "_fixture_" + os.path.splitext(os.path.basename(path))[0]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.trigger


# ---------------------------------------------------------------------------
# registry completeness: every code has a seeded-defect fixture
# ---------------------------------------------------------------------------
def test_every_registry_code_has_a_fixture():
    seeded = {_fixture_code(p)
              for p in _fixtures("wlk*.yaml")
              + _fixtures(os.path.join("lint", "wlk*.py"))
              + _fixtures(os.path.join("runtime", "wlk*.py"))
              + _fixtures(os.path.join("races", "wlk*.py"))}
    missing = sorted(set(REGISTRY) - seeded)
    assert not missing, f"registry codes without a seeded fixture: {missing}"


def test_every_fixture_names_a_registry_code():
    for p in (_fixtures("wlk*.yaml")
              + _fixtures(os.path.join("lint", "wlk*.py"))
              + _fixtures(os.path.join("runtime", "wlk*.py"))
              + _fixtures(os.path.join("races", "wlk*.py"))):
        assert _fixture_code(p) in REGISTRY, p


# ---------------------------------------------------------------------------
# pass 1: workflow-analyzer fixtures (WLK0xx / WLK1xx / WLK2xx)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("path", _fixtures("wlk*.yaml"),
                         ids=lambda p: os.path.basename(p))
def test_yaml_fixture_flags_its_code(path):
    code = _fixture_code(path)
    findings = workflow.analyze_file(path)
    hits = [d for d in findings if d.code == code]
    assert hits, (f"{os.path.basename(path)} expected {code}, got "
                  f"{[d.code for d in findings]}")
    d = hits[0]
    assert d.severity == REGISTRY[code][0]
    assert d.location.file == path
    expect = _expectations(path)
    if "task" in expect:
        assert d.location.task == expect["task"], d.render()
    if "port" in expect:
        assert d.location.port == expect["port"], d.render()
    if code not in ("WLK002",):  # structure errors may anchor nowhere
        assert d.location.line is not None, d.render()


def test_analyzer_collects_multiple_violations_in_one_pass():
    # graph.py raises on the FIRST violation; the analyzer must keep going
    # (collection is per-port: one diagnostic per broken port, plus every
    # task-level violation)
    text = """
tasks:
  - func: sim
    outports:
      - filename: data.h5
        prefetch: 2
  - func: viz
    inports:
      - filename: data.h5
        queue_depth: 0
      - filename: aux.h5
        weight: 0
"""
    codes = sorted(d.code for d in workflow.analyze_source(text))
    assert codes == ["WLK101", "WLK105", "WLK108"]


def test_analyzer_matches_graph_first_error_message():
    # dedup contract: the analyzer's message for a violation is the exact
    # string core.graph raises for the same YAML
    import yaml as _yaml
    from repro.core.graph import WorkflowGraph
    text = """
tasks:
  - func: viz
    inports:
      - filename: data.h5
        io_freq: -3
"""
    with pytest.raises(ValueError) as ei:
        WorkflowGraph.from_yaml(_yaml.safe_load(text))
    (d,) = list(workflow.analyze_source(text))
    assert d.code == "WLK102"
    assert d.message == str(ei.value)


# ---------------------------------------------------------------------------
# pass 2 (static half): AST-lint fixtures (WLK30x)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("path", _fixtures(os.path.join("lint", "wlk*.py")),
                         ids=lambda p: os.path.basename(p))
def test_lint_fixture_flags_its_code(path):
    code = _fixture_code(path)
    findings = astlint.lint_file(path)
    hits = [d for d in findings if d.code == code]
    assert hits, (f"{os.path.basename(path)} expected {code}, got "
                  f"{[d.code for d in findings]}")
    assert hits[0].location.file == path
    assert hits[0].location.line is not None


# ---------------------------------------------------------------------------
# pass 2 (runtime half) + programmatic rules (WLK118, WLK31x)
# ---------------------------------------------------------------------------
@pytest.fixture
def lockcheck_on(monkeypatch):
    monkeypatch.setenv("WILKINS_LOCKCHECK", "1")
    lockcheck.registry().reset()
    yield lockcheck.registry()
    lockcheck.registry().reset()


def test_wlk118_fixture_rejects_bad_rescale_request():
    trigger = _load_trigger(
        os.path.join(FIXDIR, "runtime", "wlk118_rescale_request.py"))
    with pytest.raises(WorkflowValidationError) as ei:
        trigger()
    assert ei.value.code == "WLK118"


@pytest.mark.parametrize("name,code", [
    ("wlk310_lock_cycle.py", "WLK310"),
    ("wlk311_blocking_under_lock.py", "WLK311"),
    ("wlk312_rank_inversion.py", "WLK312"),
])
def test_runtime_fixture_records_its_code(lockcheck_on, name, code):
    _load_trigger(os.path.join(FIXDIR, "runtime", name))()
    codes = {d.code for d in lockcheck_on.findings()}
    assert code in codes, f"{name} expected {code}, recorded {codes}"


def test_lockcheck_clean_nesting_records_no_findings(lockcheck_on):
    # canonical order: serve (10) -> supervisor (20) -> channel CV (30)
    serve = lockcheck.CheckedLock("vol.serve:sim[0]")
    sup = lockcheck.CheckedLock("supervisor:run")
    cv = lockcheck.CheckedCondition("channel.cv:data.h5")
    with serve:
        with sup:
            with cv:
                pass
    assert len(lockcheck_on.findings()) == 0
    lockcheck_on.assert_clean()


def test_lockcheck_wait_releases_held_entry(lockcheck_on):
    # a parked waiter must not count as "holding" its CV: grabbing a
    # coarser lock from inside wait's predicate re-check is what the
    # notify path does, and it must not read as an order inversion
    cv = lockcheck.CheckedCondition("channel.cv:data.h5")
    with cv:
        assert lockcheck_on.held() == ["channel.cv:data.h5"]
        cv.wait(timeout=0.01)
        assert lockcheck_on.held() == ["channel.cv:data.h5"]
    assert lockcheck_on.held() == []


def test_lockcheck_reentrant_same_object_is_not_a_violation(lockcheck_on):
    cv = lockcheck.CheckedCondition("channel.cv:data.h5")
    with cv:
        with cv:
            pass
    assert len(lockcheck_on.findings()) == 0


def test_make_lock_returns_plain_primitives_when_disabled(monkeypatch):
    monkeypatch.delenv("WILKINS_LOCKCHECK", raising=False)
    import threading
    assert isinstance(lockcheck.make_lock("leaf:x"), type(threading.Lock()))
    assert isinstance(lockcheck.make_condition("leaf:x"),
                      threading.Condition)


# ---------------------------------------------------------------------------
# zero findings over the shipped tree: examples + core
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("path", EXAMPLES,
                         ids=lambda p: os.path.basename(p))
def test_example_workflows_are_clean(path):
    findings = workflow.analyze_file(path)
    assert len(findings) == 0, "\n" + findings.render_text()


def test_examples_embed_workflows():
    # the zero-findings sweep above is vacuous if discovery breaks
    assert sum(len(workflow.load_workflows(p)) for p in EXAMPLES) >= 7


def test_core_tree_lints_clean():
    findings = astlint.lint_paths([SRC_TREE])
    assert len(findings) == 0, "\n" + findings.render_text()


# ---------------------------------------------------------------------------
# diagnostics plumbing: suppressions, renderers, CLI
# ---------------------------------------------------------------------------
def test_line_suppression_comment():
    text = """
tasks:
  - func: sim
    outports:
      - filename: data.h5
  - func: viz
    inports:
      - filename: data.h5
        queue_depth: 0   # wilkins: ignore[WLK101]
"""
    assert len(workflow.analyze_source(text)) == 0


def test_line_suppression_bare_ignores_all_codes():
    sup = line_suppressions("x: 1  # wilkins: ignore\n")
    assert sup == {1: None}


def test_line_suppression_only_covers_its_line_and_codes():
    text = """
tasks:
  - func: sim
    outports:
      - filename: data.h5
  - func: viz
    inports:
      - filename: data.h5
        queue_depth: 0   # wilkins: ignore[WLK999]
"""
    assert [d.code for d in workflow.analyze_source(text)] == ["WLK101"]


def test_doc_level_suppression():
    text = """
lint:
  ignore: [WLK204]
tasks:
  - func: viz
    inports:
      - filename: ghost.h5
"""
    assert len(workflow.analyze_source(text)) == 0


def test_render_json_shape():
    f = Findings([Diagnostic("WLK101", "boom",
                             Location(file="w.yaml", line=3, task="viz",
                                      port="data.h5"))])
    doc = json.loads(f.render_json())
    assert doc["counts"] == {"total": 1, "error": 1, "warning": 0, "info": 0}
    (d,) = doc["findings"]
    assert d["code"] == "WLK101"
    assert d["severity"] == Severity.ERROR
    assert d["location"] == {"file": "w.yaml", "line": 3, "task": "viz",
                             "port": "data.h5"}


def test_render_text_sorts_errors_first():
    f = Findings([Diagnostic("WLK224", "info finding"),
                  Diagnostic("WLK101", "error finding")])
    lines = f.render_text().splitlines()
    assert "WLK101" in lines[0]
    assert lines[-1] == "2 finding(s), 1 error(s)"


def test_cli_check_exit_codes(capsys):
    bad = os.path.join(FIXDIR, "wlk101_queue_depth.yaml")
    assert cli_main(["check", bad]) == 1
    assert "WLK101" in capsys.readouterr().out
    clean = EXAMPLES[0]
    assert cli_main(["check", clean]) == 0


def test_cli_strict_promotes_warnings(capsys):
    warn = os.path.join(FIXDIR, "wlk204_unmatched_inport.yaml")
    assert cli_main(["check", warn]) == 0
    assert cli_main(["check", "--strict", warn]) == 1
    capsys.readouterr()


def test_cli_json_output(capsys):
    bad = os.path.join(FIXDIR, "wlk101_queue_depth.yaml")
    assert cli_main(["check", "--json", bad]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["counts"]["error"] == 1


def test_cli_lint_subcommand(capsys):
    fixture = os.path.join(FIXDIR, "lint", "wlk302_if_guarded_wait.py")
    assert cli_main(["lint", fixture]) == 1
    assert "WLK302" in capsys.readouterr().out
    assert cli_main(["lint", SRC_TREE]) == 0
    capsys.readouterr()


def test_cli_codes_lists_registry(capsys):
    assert cli_main(["codes"]) == 0
    out = capsys.readouterr().out
    for code in REGISTRY:
        assert code in out


# ---------------------------------------------------------------------------
# dedup: graph/driver delegate to the shared rules
# ---------------------------------------------------------------------------
def test_graph_errors_carry_diagnostic_codes():
    import yaml as _yaml
    from repro.core.graph import WorkflowGraph
    text = """
tasks:
  - func: viz
    inports:
      - filename: data.h5
        weight: 0
"""
    with pytest.raises(WorkflowValidationError) as ei:
        WorkflowGraph.from_yaml(_yaml.safe_load(text))
    assert ei.value.code == "WLK105"
    assert ei.value.task == "viz"
    assert ei.value.port == "data.h5"


def test_driver_rescale_request_uses_shared_rules():
    from repro.core.graph import WorkflowGraph
    import yaml as _yaml
    g = WorkflowGraph.from_yaml(_yaml.safe_load("""
tasks:
  - func: sim
    outports:
      - filename: data.h5
  - func: viz
    inports:
      - filename: data.h5
"""))
    with pytest.raises(WorkflowValidationError) as ei:
        rules.validate_rescale_request(g, "viz")
    assert ei.value.code == "WLK118"
    rules.validate_rescale_request(g, "viz", nslots=2)  # legal target
