"""Logical-axis sharding rules, spec resolution, mesh filtering, HLO parsing."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import hlo
from repro.parallel.sharding import (DEFAULT_RULES, SERVE_RULES, ShardingRules,
                                     logical_to_spec, tree_shardings, use_mesh,
                                     weight)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_logical_to_spec_basic():
    m = _mesh1()
    spec = logical_to_spec(("batch", None, "tensor"), m, DEFAULT_RULES)
    assert spec == P("data", None, "model")  # pod filtered out (not in mesh)


def test_logical_axis_dedupe():
    """An axis name may appear only once in a PartitionSpec: batch wins."""
    m = _mesh1()
    spec = logical_to_spec(("batch", "fsdp"), m, DEFAULT_RULES)
    assert spec == P("data", None)


def test_serve_rules_kvseq():
    m = _mesh1()
    spec = logical_to_spec((None, "batch", "kvseq", "kv", None), m, SERVE_RULES)
    assert spec == P(None, "data", "model", None, None)
    spec_d = logical_to_spec((None, "batch", "kvseq", "kv", None), m, DEFAULT_RULES)
    assert spec_d == P(None, "data", None, None, None)


def test_rules_with_override():
    r = DEFAULT_RULES.with_(seq="model", weight_gather=True)
    assert r.lookup("seq") == "model"
    assert r.weight_gather
    assert DEFAULT_RULES.lookup("seq") is None and not DEFAULT_RULES.weight_gather


def test_tree_shardings_handles_replicated_sentinel():
    m = _mesh1()
    tree = {"a": ("fsdp", "tensor"), "b": (), "c": {"d": (None,)}}
    sh = tree_shardings(m, tree)
    assert sh["b"].spec == P()
    assert sh["a"].spec == P("data", "model")


def test_weight_gather_constrain():
    m = _mesh1()
    x = jax.numpy.ones((4, 4))
    with use_mesh(m, DEFAULT_RULES.with_(weight_gather=True)):
        y = weight(x, ("fsdp", "tensor"))
        assert y.shape == x.shape
    with use_mesh(m, DEFAULT_RULES):
        y2 = weight(x, ("fsdp", "tensor"))
        assert y2 is x  # identity when off


def test_constrain_noop_without_mesh():
    from repro.parallel.sharding import constrain

    x = jax.numpy.ones((2, 2))
    assert constrain(x, ("batch", None)) is x


# ------------------------------------------------------------- HLO parsing
HLO_SAMPLE = """
HloModule test

%body.1 (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[16,128])) -> pred[] {
  %c = s32[] constant(22)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %ag = f32[256,128]{1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %w = (s32[], f32[16,128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"22"}}
  ROOT %r = f32[16,128] get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_while_scaling():
    stats = hlo.collective_bytes(HLO_SAMPLE)
    # all-gather once: 256*128*4 bytes; all-reduce in loop: 16*128*4*2 * 22
    assert stats.bytes_by_kind["all-gather"] == 256 * 128 * 4
    assert stats.bytes_by_kind["all-reduce"] == 16 * 128 * 4 * 2 * 22
    assert stats.count_by_kind["all-reduce"] == 22


def test_shape_bytes_parsing():
    assert hlo._shape_bytes("bf16[2,3]") == 12
    assert hlo._shape_bytes("f32[] ") == 4
    assert hlo._shape_bytes("(f32[2], s8[4])") == 12


def test_roofline_terms():
    r = hlo.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0,
                     model_flops=98.5e12)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.roofline_fraction - 0.5) < 1e-9


def test_analytic_stats_scale_with_shape():
    from repro.configs import SHAPES, get_config

    cfg = get_config("tinyllama-1.1b")
    train = SHAPES[0]
    a256 = hlo.analytic_stats(cfg, train, n_data=16, n_model=16)
    a512 = hlo.analytic_stats(cfg, train, n_data=32, n_model=16)
    assert a512["flops"] < a256["flops"]  # more devices -> fewer flops each
